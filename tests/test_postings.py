"""Posting-list mechanics: O(1) head truncation, compaction, unordered
filtering (the paper's §6.2 circular-buffer behavior)."""

import numpy as np

from repro.core.postings import ItemMeta, PostingList, ScoreAccumulator


def test_append_and_active():
    pl = PostingList()
    for i in range(100):
        pl.append(i, i * 0.1, i * 0.01, float(i))
    ids, vals, pnorms, ts = pl.active()
    assert len(pl) == 100
    np.testing.assert_array_equal(ids, np.arange(100))
    assert np.allclose(ts, np.arange(100.0))


def test_truncate_ordered():
    pl = PostingList()
    for i in range(50):
        pl.append(i, 1.0, 0.0, float(i))
    pruned = pl.truncate_before_time(20.0)
    assert pruned == 20
    ids, _, _, ts = pl.active()
    assert ids[0] == 20 and ts.min() == 20.0
    # truncating everything resets to empty
    assert pl.truncate_before_time(1e9) == 30
    assert len(pl) == 0
    # reusable after reset
    pl.append(99, 1.0, 0.0, 99.0)
    assert len(pl) == 1


def test_truncate_is_amortized_o1():
    """Head advance must not copy: repeated appends + truncations stay
    linear (regression guard for the compaction threshold)."""
    pl = PostingList()
    t = 0.0
    for _ in range(2000):
        t += 1.0
        pl.append(int(t), 1.0, 0.0, t)
        pl.truncate_before_time(t - 10.0)
        assert len(pl) <= 11


def test_filter_unordered():
    pl = PostingList()
    ts = [5.0, 1.0, 9.0, 3.0, 7.0]   # out of order (re-indexing case)
    for i, t in enumerate(ts):
        pl.append(i, float(i), 0.0, t)
    pruned = pl.filter_expired_unordered(4.0)
    assert pruned == 2
    ids, _, _, t_out = pl.active()
    assert set(ids.tolist()) == {0, 2, 4}
    assert (t_out >= 4.0).all()


def test_item_meta_rebase():
    m = ItemMeta()
    for uid in range(10):
        m.add(uid, float(uid), uid + 1, 0.5)
    m.rebase(6)
    t, nnz, vm = m.lookup(np.array([6, 9]))
    assert t.tolist() == [6.0, 9.0]
    assert nnz.tolist() == [7, 10]
    m.rebase(100)   # rebase past the end empties it
    assert m.n == 0


def test_score_accumulator_kill_semantics():
    acc = ScoreAccumulator(base=0, span=8)
    acc.score[2] = 0.5
    acc.score[3] = 0.4
    acc.killed[3] = True
    acc.touched.append(np.array([2, 3]))
    cands = acc.candidates()
    assert cands.tolist() == [2]
