"""Data pipeline: determinism, sharding, exact resume, dedup filtering."""

import numpy as np
import pytest

from repro.data.pipeline import DedupFilter, TokenPipeline, hashing_embed


def test_determinism_and_resume():
    p1 = TokenPipeline(vocab_size=1000, batch=4, seq_len=16, seed=3)
    b1 = [p1.next_batch() for _ in range(5)]
    p2 = TokenPipeline(vocab_size=1000, batch=4, seq_len=16, seed=3)
    [p2.next_batch() for _ in range(3)]
    state = p2.checkpoint_state()
    p3 = TokenPipeline(vocab_size=1000, batch=4, seq_len=16, seed=0)
    p3.restore_state(state)
    b3 = [p3.next_batch() for _ in range(2)]
    np.testing.assert_array_equal(b1[3]["tokens"], b3[0]["tokens"])
    np.testing.assert_array_equal(b1[4]["tokens"], b3[1]["tokens"])


def test_shards_disjoint():
    hosts = [
        TokenPipeline(vocab_size=50_000, batch=4, seq_len=32, seed=1,
                      host_id=h, num_hosts=4)
        for h in range(4)
    ]
    batches = [h.next_batch()["tokens"] for h in hosts]
    # different hosts generate different shards
    for i in range(4):
        for j in range(i):
            assert not np.array_equal(batches[i], batches[j])


def test_labels_are_shifted():
    p = TokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=0)
    b = p.next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_hashing_embed_similarity_structure(rng):
    base = rng.integers(1, 50_000, (1, 128))
    near = base.copy()
    near[0, :6] = rng.integers(1, 50_000, 6)       # ~5% token noise
    far = rng.integers(1, 50_000, (1, 128))
    e = hashing_embed(np.concatenate([base, near, far]), dim=256)
    assert e[0] @ e[1] > 0.85
    assert abs(e[0] @ e[2]) < 0.5


def test_dedup_filter_drops_planted_duplicates():
    ded = DedupFilter(theta=0.85, lam=0.05, dim=256, capacity=512)
    rng = np.random.default_rng(0)
    doc = rng.integers(1, 50_000, (1, 128))
    batch = np.concatenate([doc, doc.copy(), rng.integers(1, 50_000, (6, 128))])
    keep = ded.filter(batch, np.linspace(0.0, 0.1, 8))
    assert keep[0]           # first (older) copy survives
    assert not keep[1]       # exact duplicate dropped
    assert keep[2:].all()    # unrelated docs survive
    # duplicates far outside the horizon are NOT dropped (time filtering)
    keep2 = ded.filter(doc, np.array([1e6]))
    assert keep2[0]


def test_pipeline_with_dedup_replaces_dropped():
    ded = DedupFilter(theta=0.8, lam=0.1, dim=256)
    p = TokenPipeline(vocab_size=50_000, batch=8, seq_len=64, seed=2,
                      dup_frac=0.5, dedup=ded)
    for _ in range(6):
        b = p.next_batch()
        assert b["tokens"].shape == (8, 64)
    assert ded.n_dropped > 0      # planted dups were caught
    assert ded.n_seen >= 48
