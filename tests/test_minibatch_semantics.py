"""MB-framework specifics the paper calls out: windows, delayed reporting,
the 2τ false-positive band removed by ApplyDecay, index rebuild counting."""

import math

import numpy as np

from repro.core import Counters, brute_force_join, join_stream, make_joiner
from repro.core.minibatch import MiniBatchJoiner, apply_decay
from repro.core.types import Pair, StreamItem, make_sparse, unit_normalize


def _item(uid, t, dims=8):
    rng = np.random.default_rng(uid)
    idx = rng.choice(dims, size=3, replace=False)
    return StreamItem(uid, t, unit_normalize(make_sparse(idx, rng.random(3) + 0.1)))


def test_mb_requires_finite_horizon():
    import pytest
    with pytest.raises(ValueError):
        make_joiner("MB", "L2", theta=0.9, lam=0.0)


def test_apply_decay_filters_2tau_band():
    """Identical vectors 1.5τ apart: raw-similar (MB tests them) but the
    decayed threshold rejects them."""
    theta, lam = 0.8, 0.5
    tau = math.log(1 / theta) / lam
    v = unit_normalize(make_sparse([0, 1], [1.0, 1.0]))
    t_of = {0: 0.0, 1: 1.5 * tau}
    raw = [Pair(0, 1, 1.0, 1.0)]
    out = apply_decay(raw, lam, theta, t_of)
    assert out == []
    t_of[1] = 0.5 * tau
    out = apply_decay(raw, lam, theta, t_of)
    assert len(out) == 1 and out[0].decayed == math.exp(-lam * 0.5 * tau)


def test_mb_rebuild_count_tracks_windows():
    theta, lam = 0.9, 1.0      # τ = log(1/0.9) ≈ 0.105
    tau = math.log(1 / theta) / lam
    c = Counters()
    j = make_joiner("MB", "L2", theta, lam, counters=c)
    items = [_item(i, i * tau * 0.9) for i in range(30)]   # ~1 item/window
    join_stream(j, items)
    # ~n·0.9 windows ⇒ at least a dozen index rebuilds (MB's overhead, the
    # reason Table 2 shows MB timing out at small τ)
    assert c.index_rebuilds >= 10


def test_mb_cross_window_pairs_found():
    theta, lam = 0.8, 0.1
    tau = math.log(1 / theta) / lam
    v = unit_normalize(make_sparse([0, 1, 2], [0.5, 0.5, 0.5]))
    # two identical items in adjacent windows, Δt < τ
    items = [
        StreamItem(0, 0.1, v),
        StreamItem(1, 0.1 + tau * 0.95, v),
        StreamItem(2, 0.1 + 2.5 * tau, v),    # third beyond horizon of #1
    ]
    got = {p.key() for p in join_stream(make_joiner("MB", "L2", theta, lam), items)}
    truth = {p.key() for p in brute_force_join(items, theta, lam)}
    assert got == truth
    assert (0, 1) in got
    assert (0, 2) not in got
