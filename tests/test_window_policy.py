"""Write-slot policy layer (DESIGN.md §11): property-based invariants of
:func:`repro.engine.window.select_write_slots` and the policy push.

Four contracts, each hypothesis-driven when the optional dependency is
present and a fixed seed sweep otherwise (same pattern as
``test_runtime.py``):

  * **uniqueness** — no two rows of a micro-batch ever select the same
    slot, under any policy (dropped rows route to the ``capacity``
    sentinel);
  * **split invariance** — pushing a batch whole or split at any point
    leaves identical ring state and cursors (``oldest`` always; ``dead``
    in the non-overflow regime, i.e. writes land on dead slots; ``quota``
    lane cursors always);
  * **quota conservation** — under arbitrary wrap, stream *k*'s items
    only ever occupy its own sub-ring, and no other stream's items leak
    in (slot counts are conserved);
  * **dead-first preference** — a live slot is never overwritten while a
    dead one exists: live overwrites equal exactly
    ``max(0, n_valid − n_dead)``.
"""

import numpy as np
import pytest

try:  # optional dev dependency: richer search when present, fixed sweep not
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.engine.window import (
    init_window,
    push_with_overflow,
    quota_partition,
    select_write_slots,
)

D = 4
K = 3
TAU = 2.0


def _random_state(rng, cap, eviction="oldest", n_lanes=K, t_now=10.0):
    """A ring in a random but reachable shape: a mix of empty slots,
    expired (dead) slots, and live slots, random cursor and lane cursors."""
    state = init_window(cap, D, n_lanes=n_lanes, eviction=eviction)
    kind = rng.integers(0, 3, cap)              # 0 empty, 1 expired, 2 live
    ts = np.full(cap, 3.0e30, np.float32)
    uids = np.full(cap, -1, np.int32)
    sids = np.full(cap, -1, np.int32)
    filled = kind > 0
    n_fill = int(filled.sum())
    uids[filled] = rng.permutation(n_fill).astype(np.int32)
    sids[filled] = rng.integers(0, n_lanes, n_fill).astype(np.int32)
    ts[kind == 1] = t_now - TAU - 1.0 - rng.random((kind == 1).sum())
    ts[kind == 2] = t_now - TAU * rng.random((kind == 2).sum())
    vecs = rng.standard_normal((cap, D)).astype(np.float32)
    vecs[~filled] = 0.0
    state = state._replace(
        vecs=jnp.asarray(vecs), ts=jnp.asarray(ts), uids=jnp.asarray(uids),
        sids=jnp.asarray(sids),
        cursor=jnp.asarray(rng.integers(0, cap), jnp.int32),
    )
    if state.lane_cursor is not None:
        state = state._replace(
            lane_cursor=jnp.asarray(
                rng.integers(0, 1 << 20, n_lanes), jnp.int32
            )
        )
    return state, kind, t_now


def _batch(rng, b, n_valid, t_now, uid0=1000):
    q = rng.standard_normal((b, D)).astype(np.float32)
    tq = (t_now + 0.01 * np.arange(b)).astype(np.float32)
    uq = np.arange(uid0, uid0 + b, dtype=np.int32)
    uq[n_valid:] = -1
    sq = rng.integers(0, K, b).astype(np.int32)
    return jnp.asarray(q), jnp.asarray(tq), jnp.asarray(uq), jnp.asarray(sq)


def _quotas(rng, cap):
    return jnp.asarray(quota_partition(cap, rng.random(K) + 0.25), jnp.int32)


# --------------------------------------------------------------------- #
# uniqueness: no two rows of a micro-batch select the same slot
# --------------------------------------------------------------------- #
def _check_unique(seed, cap, b, eviction):
    rng = np.random.default_rng(seed)
    ev = "quota" if eviction == "quota" else "oldest"
    state, _, t_now = _random_state(rng, cap, eviction=ev)
    n_valid = int(rng.integers(0, min(b, cap) + 1))
    _, _, _, sq = _batch(rng, b, n_valid, t_now)
    quotas = _quotas(rng, cap) if eviction == "quota" else None
    dest, _, _, self_evicted = select_write_slots(
        state, b, jnp.int32(n_valid), jnp.float32(t_now + 0.01 * b), TAU,
        sq=sq, eviction=eviction, quotas=quotas,
    )
    dest = np.asarray(dest)
    written = dest[dest < cap]
    assert written.size == np.unique(written).size, (eviction, dest)
    # every valid row either writes a slot or is an accounted self-eviction
    se = np.asarray(self_evicted)
    assert ((dest < cap) | se)[:n_valid].all()
    assert (dest[n_valid:] == cap).all() and not se[n_valid:].any()


@pytest.mark.parametrize("eviction", ["oldest", "dead", "quota"])
@pytest.mark.parametrize("seed,cap,b", [(0, 16, 8), (1, 32, 32), (2, 7, 5)])
def test_unique_slots_sweep(seed, cap, b, eviction):
    _check_unique(seed, cap, b, eviction)


# --------------------------------------------------------------------- #
# split invariance: one push vs the same rows split at any boundary
# --------------------------------------------------------------------- #
def _push(state, q, tq, uq, sq, n_valid, eviction, quotas):
    t_max = jnp.max(
        jnp.where(jnp.arange(q.shape[0]) < n_valid, tq, -jnp.inf),
        initial=-jnp.inf,
    )
    return push_with_overflow(
        state, q, tq, uq, jnp.int32(n_valid), t_max, TAU, sq=sq,
        eviction=eviction, quotas=quotas,
    )


def _states_equal(a, b):
    for name, x, y in zip(a._fields, a, b):
        if x is None and y is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=name
        )


def _check_split_invariance(seed, cap, b, eviction):
    rng = np.random.default_rng(seed)
    ev = "quota" if eviction == "quota" else "oldest"
    state, kind, t_now = _random_state(rng, cap, eviction=ev)
    if eviction == "dead":
        # the guaranteed regime: enough dead slots for the whole batch
        # (overflow overwrites are policy-dependent across splits by design)
        b = min(b, int((kind != 2).sum()))
        if b == 0:
            return
    q, tq, uq, sq = _batch(rng, b, b, t_now)
    quotas = _quotas(rng, cap) if eviction == "quota" else None
    whole = _push(state, q, tq, uq, sq, b, eviction, quotas)
    cut = int(rng.integers(0, b + 1))
    first = _push(state, q[:cut], tq[:cut], uq[:cut], sq[:cut], cut,
                  eviction, quotas)
    second = _push(first, q[cut:], tq[cut:], uq[cut:], sq[cut:], b - cut,
                   eviction, quotas)
    _states_equal(whole, second)


@pytest.mark.parametrize("eviction", ["oldest", "dead", "quota"])
@pytest.mark.parametrize("seed,cap,b", [
    (0, 16, 8), (1, 32, 20), (2, 9, 9), (3, 24, 1),
])
def test_split_invariance_sweep(seed, cap, b, eviction):
    _check_split_invariance(seed, cap, b, eviction)


# --------------------------------------------------------------------- #
# quota: sub-ring containment is conserved under arbitrary wrap
# --------------------------------------------------------------------- #
def _check_quota_conservation(seed, cap, rounds):
    rng = np.random.default_rng(seed)
    state = init_window(cap, D, n_lanes=K, eviction="quota")
    quotas = _quotas(rng, cap)
    offs = np.concatenate([[0], np.cumsum(np.asarray(quotas))[:-1]])
    uid0 = 0
    t = 1.0
    for _ in range(rounds):
        b = int(rng.integers(1, cap + 1))
        q, tq, uq, sq = _batch(rng, b, b, t, uid0=uid0)
        state = _push(state, q, tq, uq, sq, b, "quota", quotas)
        uid0 += b
        t += 0.5
        sids = np.asarray(state.sids)
        uids = np.asarray(state.uids)
        for k in range(K):
            lo, hi = int(offs[k]), int(offs[k]) + int(quotas[k])
            inside = sids[lo:hi]
            # stream k's sub-ring holds only stream-k items (or empties) …
            assert set(np.unique(inside)) <= {-1, k}, (k, inside)
            # … and stream k's items never appear anywhere else
            outside = np.concatenate([sids[:lo], sids[hi:]])
            assert not (outside == k).any(), k
        # lane cursors stay inside their sub-rings
        lc = np.asarray(state.lane_cursor)
        assert (0 <= lc).all() and (lc < np.asarray(quotas)).all()
        assert (uids[sids == -1] == -1).all()


@pytest.mark.parametrize("seed,cap,rounds", [(0, 16, 6), (1, 31, 8), (2, 8, 12)])
def test_quota_conservation_sweep(seed, cap, rounds):
    _check_quota_conservation(seed, cap, rounds)


# --------------------------------------------------------------------- #
# dead-first: live overwrites happen only once every dead slot is used
# --------------------------------------------------------------------- #
def _check_dead_first_preference(seed, cap, b):
    rng = np.random.default_rng(seed)
    state, kind, t_now = _random_state(rng, cap)
    b = min(b, cap)
    n_valid = int(rng.integers(0, b + 1))
    q, tq, uq, sq = _batch(rng, b, n_valid, t_now)
    t_max = jnp.float32(t_now + 0.01 * b)
    dead = np.asarray(
        (state.uids < 0) | (t_max - state.ts > TAU)
    )
    dest, _, _, _ = select_write_slots(
        state, b, jnp.int32(n_valid), t_max, TAU, sq=sq, eviction="dead",
    )
    dest = np.asarray(dest)
    written = dest[dest < cap]
    live_hits = int((~dead[written]).sum())
    assert live_hits == max(0, n_valid - int(dead.sum()))
    # and the policy push counts exactly those as overflow
    new = _push(state, q, tq, uq, sq, n_valid, "dead", None)
    assert int(new.overflow) == live_hits
    assert int(np.asarray(new.lane_overflow).sum()) == live_hits


@pytest.mark.parametrize("seed,cap,b", [(0, 16, 16), (1, 12, 7), (2, 6, 6)])
def test_dead_first_preference_sweep(seed, cap, b):
    _check_dead_first_preference(seed, cap, b)


# --------------------------------------------------------------------- #
# quota self-eviction: wrapping one sub-ring inside a single micro-batch
# keeps the newest writer per slot and counts the earlier rows as overflow
# --------------------------------------------------------------------- #
def test_quota_self_eviction_accounted():
    state = init_window(6, D, n_lanes=2, eviction="quota")
    quotas = jnp.asarray([2, 4], jnp.int32)
    rng = np.random.default_rng(5)
    b = 5
    q = jnp.asarray(rng.standard_normal((b, D)), jnp.float32)
    tq = jnp.asarray(1.0 + 0.01 * np.arange(b), jnp.float32)
    uq = jnp.asarray(np.arange(b), jnp.int32)
    sq = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)   # 3 rows into a 2-slot ring
    new = _push(state, q, tq, uq, sq, b, "quota", quotas)
    uids = np.asarray(new.uids)
    # newest two of stream 0 survive, in sub-ring order (cursor wrapped)
    assert sorted(uids[:2].tolist()) == [1, 2]
    assert uids[2:4].tolist() == [3, 4] and (uids[4:] == -1).all()
    assert int(new.overflow) == 1
    assert np.asarray(new.lane_overflow).tolist() == [1, 0]


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cap=st.integers(2, 48),
        b=st.integers(1, 48),
        eviction=st.sampled_from(["oldest", "dead", "quota"]),
    )
    def test_unique_slots_property(seed, cap, b, eviction):
        _check_unique(seed, cap, min(b, cap), eviction)

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cap=st.integers(2, 48),
        b=st.integers(1, 48),
        eviction=st.sampled_from(["oldest", "dead", "quota"]),
    )
    def test_split_invariance_property(seed, cap, b, eviction):
        _check_split_invariance(seed, cap, min(b, cap), eviction)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cap=st.integers(3, 32),
        rounds=st.integers(1, 8),
    )
    def test_quota_conservation_property(seed, cap, rounds):
        _check_quota_conservation(seed, cap, rounds)

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cap=st.integers(1, 48),
        b=st.integers(1, 48),
    )
    def test_dead_first_preference_property(seed, cap, b):
        _check_dead_first_preference(seed, cap, b)
