"""Cross-impl conformance suite: every engine variant vs the dense oracle.

One parametrized grid — engine ∈ {single, sharded×{2,4}} × level-1 impl ∈
{pallas, scan, dense} × tenants ∈ {None, K=8} × ring {wrapped, unwrapped}
× emission {lossless, overflow} × eviction policy {oldest, dead, quota}
× strip index {off, l2gate} (DESIGN.md §13; "dense" pairs only with off) —
asserting the one contract every current and future engine variant must
satisfy (DESIGN.md §8/§10/§11):

  * **exactness** — with no drop counter firing, the emitted pair set
    equals the dense-oracle brute force pair-for-pair (per tenant on the
    multi-tenant path), scores match the oracle's decayed similarities,
    and every score clears its own tenant's θ;
  * **overflow honesty** — under a tight ``max_pairs`` budget the
    survivors are a subset of the truth and
    ``survivors + pairs_dropped == truth`` with the per-level split
    consistent (``dropped == dropped_budget + dropped_tile``);
  * **liveness** — the ring (wrapped or not) never overwrote a live item
    (``overflow == 0``), which is what makes the whole-stream brute force
    a valid oracle;
  * **invariance** — per-tenant emissions are identical across shard
    counts (P ∈ {1, 2, 4}) and coalescing plans, because uids assign at
    admission and the round-robin deal is uid-ordered;
  * **policy conformance** — ``oldest`` cells are byte-identical to the
    pre-policy ring (numpy reference simulation: same slots, cursor, and
    overflow counter); every policy stays pinned to the dense oracle
    whenever its overflow counters are zero; ``window_overflow_by_tenant``
    sums exactly to ``window_overflow``; and the **quota isolation
    invariant** — a bursty tenant at 10× rate cannot change a
    within-quota tenant's emitted pair set, while ``oldest`` demonstrably
    loses pairs on the same traffic (DESIGN.md §11).

Sharded cells run in-process when the session already has enough devices
(the CI multi-device leg forces 8 host devices) and fall back to a
subprocess with ``--xla_force_host_platform_device_count`` otherwise, so
the grid is enforced on the plain single-device tier-1 run too.

This file is THE conformance gate: a new engine variant (new backend,
new merge level, new tenancy mode) earns its place by adding a cell
here, not by growing a bespoke test file.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.synth import bursty_tenant_traffic
from repro.engine import EngineConfig, ShardedStreamEngine, StreamEngine
from repro.runtime import MultiTenantRuntime, ShardedFacade, TenantTable

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TESTS = os.path.dirname(os.path.abspath(__file__))

D, MB = 32, 16
K = 8
# per-tenant (θ, λ): horizons are deliberately short (τ_max ≈ 1.2 time
# units at global arrival rate ≈ K/unit ⇒ ~10 live items) so the wrapped
# 64-slot ring never evicts a live item and the brute force stays exact
THETAS = [0.8, 0.7, 0.9, 0.8, 0.75, 0.85, 0.8, 0.7]
LAMS = [0.3, 0.5, 1.0, 0.4, 0.3, 0.6, 0.8, 0.5]
N_PER = 24                 # items per tenant stream
N_SINGLE = 192             # items in the single-tenant stream
CAP_WRAPPED = 64           # total ring slots — wraps ~3× over the stream
CAP_BIG = 256              # total ring slots — never wraps

MODES = [
    ("unwrapped", CAP_BIG, False),
    ("wrapped", CAP_WRAPPED, False),
    ("overflow", CAP_BIG, True),
]


def _cfg(
    impl: str, cap_total: int, overflow: bool, shards: int,
    eviction: str = "oldest", n_streams: int = 1,
    l2_gate=None,
) -> EngineConfig:
    quotas = None
    if eviction == "quota":
        # equal static split of the per-shard ring (sub-rings shard-local)
        quotas = (cap_total // shards // n_streams,) * n_streams
    return EngineConfig(
        theta=0.8, lam=0.05, capacity=cap_total // shards, d=D,
        micro_batch=MB, max_pairs=2 if overflow else 4096,
        tile_k=MB * MB,            # block² — level 1 is lossless by design
        block_q=MB, block_w=MB, chunk_d=32, join_impl=impl,
        eviction=eviction, quotas=quotas, l2_gate=l2_gate,
    )


def _dup_stream(n: int, seed: int, dup_frac: float = 0.35):
    """A stream with near-duplicates planted at small Δt (dup chains
    included), so pairs exist inside even the strictest tenant's horizon
    and overflow cells reliably exceed a 2-pair budget."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0, n)
    dup = rng.random(n) < dup_frac
    dup[0] = False
    gaps[dup] = 0.02 + 0.03 * rng.random(int(dup.sum()))
    ts = np.cumsum(gaps)
    v = rng.standard_normal((n, D))
    for i in range(1, n):
        if dup[i]:
            v[i] = v[i - 1] + 0.03 * rng.standard_normal(D)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    return v.astype(np.float32), ts


def _tenant_events():
    """K interleaved tenant streams in one global admission order."""
    streams = [_dup_stream(N_PER, 500 + k) for k in range(K)]
    events = sorted(
        (float(streams[k][1][i]), k, i)
        for k in range(K) for i in range(N_PER)
    )
    return streams, events


def _truth(vecs, ts, theta, lam, uid_of=None):
    """Dense-oracle brute force: ``{(uid_lo, uid_hi): score}``."""
    dec = (vecs @ vecs.T) * np.exp(-lam * np.abs(ts[:, None] - ts[None, :]))
    out = {}
    n = vecs.shape[0]
    for i in range(n):
        for j in range(i):
            if dec[i, j] >= theta:
                a, b = (i, j) if uid_of is None else (uid_of[i], uid_of[j])
                out[(min(a, b), max(a, b))] = float(dec[i, j])
    return out


def _pair_scores(ua, ub, sc):
    return {
        (min(a, b), max(a, b)): s
        for a, b, s in zip(ua.tolist(), ub.tolist(), sc.tolist())
    }


def _check(got: dict, truth: dict, stats: dict, overflow: bool, label):
    """The conformance contract shared by every cell."""
    assert truth, f"{label}: vacuous cell — no true pairs"
    assert stats["window_overflow"] == 0, label
    by_tenant = stats.get("window_overflow_by_tenant")
    if by_tenant is not None:     # lane sums match the global counter
        assert sum(by_tenant) == stats["window_overflow"], label
    assert stats["pairs_dropped"] == (
        stats["pairs_dropped_budget"] + stats["pairs_dropped_tile"]
    ), label
    assert got.keys() <= truth.keys(), (
        label, sorted(got.keys() - truth.keys())[:5]
    )
    for k in got:
        assert abs(got[k] - truth[k]) < 1e-5, (label, k)
    if overflow:
        assert stats["pairs_dropped"] > 0, label
        assert len(got) + stats["pairs_dropped"] == len(truth), label
    else:
        assert stats["pairs_dropped"] == 0, label
        assert got.keys() == truth.keys(), (
            label, sorted(truth.keys() - got.keys())[:5]
        )


def _mesh(shards: int):
    import jax

    return jax.make_mesh((shards,), ("data",))


def run_cell(
    impl: str, tenants, shards: int, mode: str, eviction: str = "oldest",
    gate: str = "auto",
) -> None:
    """One conformance cell; raises AssertionError on contract violation.

    ``gate`` is the strip-index axis (DESIGN.md §13): ``"off"`` disables
    the device-resident L2/prefix gate, ``"l2gate"`` force-enables it
    (only meaningful for the hierarchical impls — ``dense`` cells must
    use ``"off"``/``"auto"``, the config rejects a forced gate there),
    ``"auto"`` keeps the config default (on for hierarchical paths)."""
    label = (impl, tenants, shards, mode, eviction, gate)
    cap_total, overflow = next(
        (c, o) for m, c, o in MODES if m == mode
    )
    cfg = _cfg(
        impl, cap_total, overflow, shards, eviction,
        n_streams=K if tenants else 1,
        l2_gate={"auto": None, "off": False, "l2gate": True}[gate],
    )
    if tenants is None:
        vecs, ts = _dup_stream(N_SINGLE, seed=29, dup_frac=0.4)
        truth = _truth(vecs, ts, cfg.theta, cfg.lam)
        eng = (
            StreamEngine(cfg) if shards == 1
            else ShardedStreamEngine(cfg, _mesh(shards))
        )
        for i in range(0, N_SINGLE, 80):      # ragged pushes → padding path
            eng.push(vecs[i:i + 80], ts[i:i + 80])
        ua, ub, sc = eng.drain_arrays()
        _check(_pair_scores(ua, ub, sc), truth, eng.stats(), overflow, label)
        return

    streams, events = _tenant_events()
    table = TenantTable(THETAS, LAMS)
    engine = None if shards == 1 else ShardedFacade(_mesh(shards))
    rt = MultiTenantRuntime(cfg, table, span=2, engine=engine)
    uid_maps = [dict() for _ in range(K)]
    for _, k, i in events:
        v, t = streams[k]
        u = rt.submit(k, v[i:i + 1], t[i:i + 1])
        uid_maps[k][i] = int(u[0])
    rt.flush(final=True)
    per = rt.drain_by_tenant()
    stats = rt.stats()
    got_all, truth_all = {}, {}
    for k in range(K):
        truth_k = _truth(*streams[k], THETAS[k], LAMS[k], uid_of=uid_maps[k])
        got_k = _pair_scores(*per[k][:3])
        # per-tenant: survivors ⊆ that tenant's truth with true scores,
        # every score over that tenant's own θ (never a looser tenant's)
        assert got_k.keys() <= truth_k.keys(), (label, k)
        assert all(s >= THETAS[k] - 1e-6 for s in got_k.values()), (label, k)
        got_all.update(got_k)
        truth_all.update(truth_k)
    _check(got_all, truth_all, stats, overflow, label)
    if shards > 1:                 # tenant-aware per-shard stats surfaced
        assert stats["n_shards"] == shards
        # per-shard lanes count each shard's merge survivors BEFORE the
        # global budget; the global-merge losses ride their own counter
        assert all(p >= 0 for p in stats["shards"]["pairs_emitted"])
        assert stats["pairs_dropped_global"] >= 0
        assert (
            sum(stats["shards"]["pairs_emitted"])
            == stats["pairs_emitted"] + stats["pairs_dropped_global"]
        )
        assert sum(stats["shards"]["window_overflow"]) == 0


def run_cells(
    impl: str, tenants, shards: int, eviction: str = "oldest",
    gate: str = "auto",
) -> None:
    for mode, _, _ in MODES:
        run_cell(impl, tenants, shards, mode, eviction, gate)


def _subprocess_cells(
    impl: str, tenants, shards: int, eviction: str = "oldest",
    gate: str = "auto",
) -> None:
    code = (
        f"import sys; sys.path.insert(0, {_TESTS!r})\n"
        f"from test_conformance import run_cells\n"
        f"run_cells({impl!r}, {tenants!r}, {shards}, {eviction!r}, {gate!r})\n"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


IMPLS = ["pallas", "scan", "dense"]
TENANTS = [None, K]
# strip-index axis (DESIGN.md §13): "l2gate" force-enables the gate on the
# hierarchical impls; "dense" has no tile launch to gate, so it only pairs
# with "off" (the config rejects l2_gate=True on a dense-oracle path)
IMPL_GATES = [
    ("pallas", "off"), ("pallas", "l2gate"),
    ("scan", "off"), ("scan", "l2gate"),
    ("dense", "off"),
]
_IG_IDS = [f"{i}-{g}" for i, g in IMPL_GATES]


@pytest.mark.parametrize("mode", [m for m, _, _ in MODES])
@pytest.mark.parametrize("tenants", TENANTS, ids=["single-stream", f"K{K}"])
@pytest.mark.parametrize("impl,gate", IMPL_GATES, ids=_IG_IDS)
def test_conformance_single_device(impl, gate, tenants, mode):
    run_cell(impl, tenants, 1, mode, gate=gate)


@pytest.mark.parametrize("tenants", TENANTS, ids=["single-stream", f"K{K}"])
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("shards", [2, 4])
def test_conformance_sharded(shards, impl, tenants):
    """All three ring/overflow modes per (shards, impl, tenants) cell —
    in-process when the session has enough devices (CI multi-device leg),
    else in a subprocess with forced host devices."""
    import jax

    if jax.device_count() >= shards:
        run_cells(impl, tenants, shards)
    else:
        _subprocess_cells(impl, tenants, shards)


# --------------------------------------------------------------------- #
# eviction-policy axis (DESIGN.md §11): every policy stays pinned to the
# dense oracle whenever its overflow counters are zero
# --------------------------------------------------------------------- #
EVICTIONS = ["dead", "quota"]          # "oldest" is every cell above


@pytest.mark.parametrize("eviction", EVICTIONS)
@pytest.mark.parametrize("tenants", TENANTS, ids=["single-stream", f"K{K}"])
@pytest.mark.parametrize("impl,gate", IMPL_GATES, ids=_IG_IDS)
def test_conformance_eviction_policies(impl, gate, tenants, eviction):
    """The wrapped ring is where policies actually differ — the write
    path reuses/partitions slots — yet with zero overflow every policy
    must emit the identical oracle pair set.  The gate axis rides along:
    every eviction policy must refresh the victim strip's summary, so a
    stale-summary bug would surface here as a missing pair."""
    run_cell(impl, tenants, 1, "wrapped", eviction, gate=gate)


@pytest.mark.parametrize("eviction", EVICTIONS)
@pytest.mark.parametrize("mode", ["unwrapped", "overflow"])
def test_conformance_eviction_modes(eviction, mode):
    run_cell("scan", K, 1, mode, eviction)


@pytest.mark.parametrize("eviction", EVICTIONS)
def test_conformance_eviction_sharded(eviction):
    """Policies compose with the shard_map fan-out: quota sub-rings are
    shard-local and the quota table rides the in_specs replicated."""
    import jax

    if jax.device_count() >= 2:
        run_cells("scan", K, 2, eviction)
    else:
        _subprocess_cells("scan", K, 2, eviction)


@pytest.mark.parametrize("gate", ["off", "l2gate"])
def test_conformance_sharded_gate_axis(gate):
    """The sharded default is gate-auto-on (every sharded cell above
    already runs gated); this pins the explicit endpoints — forced-on
    (per-shard summaries under the nested StripSummary P-specs) and
    forced-off — to the same oracle."""
    import jax

    if jax.device_count() >= 2:
        run_cells("scan", K, 2, gate=gate)
    else:
        _subprocess_cells("scan", K, 2, gate=gate)


def test_oldest_ring_byte_identical_to_prerefactor():
    """Tentpole acceptance: the default policy's ring is byte-identical
    to the pre-refactor oldest-first overwrite — same slot contents, same
    cursor, same overflow counter — against a numpy reference that
    implements the old `push_with_overflow` verbatim."""
    cfg = _cfg("scan", CAP_WRAPPED, False, 1)
    eng = StreamEngine(cfg)
    vecs, ts = _dup_stream(N_SINGLE, seed=29, dup_frac=0.4)
    cap, mb, tau = cfg.capacity, cfg.micro_batch, cfg.tau
    ref_v = np.zeros((cap, D), np.float32)
    ref_t = np.full(cap, 3.0e30, np.float32)
    ref_u = np.full(cap, -1, np.int32)
    cur = ovf = uid = 0
    for i in range(0, N_SINGLE, 80):
        eng.push(vecs[i:i + 80], ts[i:i + 80])
        for j in range(i, min(i + 80, N_SINGLE), mb):   # push sizes are
            # multiples of mb (80, 80, 32) — no padding path here
            pos = (cur + np.arange(mb)) % cap
            t_max = np.float32(ts[j:j + mb].max())
            ovf += int(
                ((ref_u[pos] >= 0) & (t_max - ref_t[pos] <= tau)).sum()
            )
            ref_v[pos] = vecs[j:j + mb]
            ref_t[pos] = ts[j:j + mb].astype(np.float32)
            ref_u[pos] = np.arange(uid, uid + mb, dtype=np.int32)
            cur = (cur + mb) % cap
            uid += mb
    eng.drain_arrays()                            # sync
    np.testing.assert_array_equal(np.asarray(eng.state.vecs), ref_v)
    np.testing.assert_array_equal(np.asarray(eng.state.ts), ref_t)
    np.testing.assert_array_equal(np.asarray(eng.state.uids), ref_u)
    assert int(eng.state.cursor) == cur
    assert int(eng.state.overflow) == ovf


# --------------------------------------------------------------------- #
# quota isolation invariant (tentpole acceptance): a bursty tenant at 10×
# rate cannot change a within-quota tenant's emitted pair set — while
# oldest-first demonstrably loses the same pairs on the same traffic
# --------------------------------------------------------------------- #
BK = 4                     # one bursty + three slow tenants
B_THETAS = [0.9, 0.8, 0.8, 0.8]
B_LAMS = [2.0, 0.1, 0.1, 0.1]     # slow τ ≈ 2.23; bursty τ ≈ 0.05
B_CAP = 32                 # total ring slots — one round overruns it
B_MB = 16
B_ROUNDS = 10
# bursty items per round ≫ 10× the slow tenants' 3: each round's 48
# arrivals exceed capacity + micro-batch ingest lag (32 + 15), so under
# oldest-first nothing from round r survives to round r+1's queries
B_BURST = 45


def _run_bursty(impl: str, shards: int, eviction: str):
    """Drive the bursty traffic through one engine cell; returns each
    slow tenant's local pair set, the per-tenant truth, and stats."""
    table = TenantTable(B_THETAS, B_LAMS)
    quotas = (
        (B_CAP // shards // BK,) * BK if eviction == "quota" else None
    )
    cfg = EngineConfig(
        theta=0.8, lam=0.1, capacity=B_CAP // shards, d=D, micro_batch=B_MB,
        max_pairs=4096, tile_k=B_MB * B_MB, block_q=B_MB, block_w=B_MB,
        chunk_d=32, join_impl=impl, eviction=eviction, quotas=quotas,
    )
    engine = None if shards == 1 else ShardedFacade(_mesh(shards))
    rt = MultiTenantRuntime(cfg, table, span=2, engine=engine)
    # the canonical flood stream (slow reposts every 1.5 units — each
    # consecutive pair within τ, the next-but-one outside it; per-round
    # arrivals exceed the whole ring so oldest-first evicts live items)
    submits, per_tenant = bursty_tenant_traffic(BK - 1, B_ROUNDS, B_BURST, D)
    local_of = [dict() for _ in range(BK)]
    counts = [0] * BK
    for k, v, t in submits:
        uids = rt.submit(k, v, t)
        for u in uids.tolist():
            local_of[k][u] = counts[k]
            counts[k] += 1
    rt.flush(final=True)
    per = rt.drain_by_tenant()
    got = []
    for k in range(BK):
        ua, ub, _ = per[k][:3]
        got.append({
            tuple(sorted((local_of[k][a], local_of[k][b])))
            for a, b in zip(ua.tolist(), ub.tolist())
        })
    truth = [
        set(_truth(*per_tenant[k], B_THETAS[k], B_LAMS[k]).keys())
        for k in range(BK)
    ]
    return got, truth, rt.stats()


def run_quota_isolation(impl: str, shards: int) -> None:
    got_q, truth, sq = _run_bursty(impl, shards, "quota")
    got_o, _, so = _run_bursty(impl, shards, "oldest")
    for k in range(1, BK):
        assert truth[k], (impl, shards, k)   # non-vacuous: pairs exist
        # the invariant: within-quota tenants emit their exact truth, and
        # none of their live items were ever overwritten
        assert got_q[k] == truth[k], (impl, shards, k)
    by_q = sq["window_overflow_by_tenant"]
    by_o = so["window_overflow_by_tenant"]
    assert sum(by_q) == sq["window_overflow"]
    assert sum(by_o) == so["window_overflow"]
    assert sum(by_q[1:]) == 0, by_q          # quota: slow tenants untouched
    # non-vacuity: oldest-first did evict slow tenants' live items and
    # lost some of their pairs on the identical traffic
    assert sum(by_o[1:]) > 0, by_o
    lost = [truth[k] - got_o[k] for k in range(1, BK)]
    assert any(lost), (impl, shards)


@pytest.mark.parametrize("impl", IMPLS)
def test_quota_isolation_single_device(impl):
    run_quota_isolation(impl, 1)


@pytest.mark.parametrize("impl", IMPLS)
def test_quota_isolation_sharded(impl):
    import jax

    if jax.device_count() >= 2:
        run_quota_isolation(impl, 2)
        return
    code = (
        f"import sys; sys.path.insert(0, {_TESTS!r})\n"
        f"from test_conformance import run_quota_isolation\n"
        f"run_quota_isolation({impl!r}, 2)\n"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


# --------------------------------------------------------------------- #
# tentpole acceptance: per-tenant emissions invariant to BOTH coalescing
# boundaries and shard count (round-robin deal is uid-ordered)
# --------------------------------------------------------------------- #
def run_invariance() -> None:
    """Same traffic through P ∈ {1, 2, 4} × three coalescing plans: nine
    runs, one per-tenant pair-score map, equal to the dense oracle."""
    import jax

    streams, events = _tenant_events()
    table = TenantTable(THETAS, LAMS)

    def run(shards, plan, flush_every):
        scfg = _cfg("scan", CAP_BIG, False, shards)
        engine = None if shards == 1 else ShardedFacade(_mesh(shards))
        rt = MultiTenantRuntime(scfg, table, span=2, engine=engine)
        uid_maps = [dict() for _ in range(K)]
        i = p = n_flush = 0
        while i < len(events):
            chunk = events[i:i + plan[p % len(plan)]]
            i += len(chunk)
            p += 1
            for _, k, j in chunk:
                v, t = streams[k]
                u = rt.submit(k, v[j:j + 1], t[j:j + 1])
                uid_maps[k][j] = int(u[0])
            n_flush += 1
            if flush_every and n_flush % flush_every == 0:
                rt.flush()
        rt.flush(final=True)
        per = rt.drain_by_tenant()
        assert rt.pairs_dropped == 0 and rt.overflow == 0
        return uid_maps, [_pair_scores(*per[k][:3]) for k in range(K)]

    rng = np.random.default_rng(3)
    plans = [([1], None), ([7], 3), (rng.integers(1, 23, 40).tolist(), 2)]
    ref_maps, ref_sets = run(1, *plans[0])
    truths = [
        _truth(*streams[k], THETAS[k], LAMS[k], uid_of=ref_maps[k])
        for k in range(K)
    ]
    for k in range(K):
        assert ref_sets[k].keys() == truths[k].keys(), k
    shard_counts = [p for p in (1, 2, 4) if jax.device_count() >= p]
    assert shard_counts == [1] or len(shard_counts) == 3
    for shards in shard_counts:
        for plan, flush_every in plans:
            maps, sets = run(shards, plan, flush_every)
            # uid assignment is admission-order — identical across plans —
            # so the pair maps must agree key-for-key, score-for-score
            assert maps == ref_maps, (shards, plan[:5], flush_every)
            for k in range(K):
                assert sets[k].keys() == ref_sets[k].keys(), (shards, k)
                for key in sets[k]:
                    assert abs(sets[k][key] - ref_sets[k][key]) < 1e-6, (
                        shards, k, key
                    )
    print(f"invariance ok over shards {shard_counts} × {len(plans)} plans")


def test_emissions_invariant_to_shards_and_coalescing():
    import jax

    if jax.device_count() >= 4:
        run_invariance()
        return
    code = (
        f"import sys; sys.path.insert(0, {_TESTS!r})\n"
        f"from test_conformance import run_invariance\n"
        f"run_invariance()\n"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "invariance ok" in r.stdout
