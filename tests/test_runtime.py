"""Multi-tenant streaming runtime (DESIGN.md §9): per-tenant exactness
under arbitrary coalescing, stream isolation, overflow accounting,
backpressure, config validation, and the fused embed→join path."""

import numpy as np
import pytest

try:  # optional dev dependency: richer search when present, fixed sweep not
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.data.synth import dense_embedding_stream, planted_duplicates
from repro.engine import EngineConfig
from repro.runtime import (
    MultiTenantRuntime,
    RequestRouter,
    TenantBackpressure,
    TenantTable,
)

K = 8
D = 64
THETAS = [0.8, 0.7, 0.9, 0.8, 0.75, 0.85, 0.8, 0.7]
LAMS = [0.05, 0.1, 0.02, 0.2, 0.05, 0.08, 0.01, 0.15]


def _cfg(**kw):
    base = dict(theta=0.8, lam=0.05, capacity=1024, d=D, micro_batch=32,
                max_pairs=2048, block_q=32, block_w=32, chunk_d=32)
    base.update(kw)
    return EngineConfig(**base)


def _tenant_streams(n_per=72, seed0=100):
    """K independent streams, interleaved into one global time order."""
    streams = [
        dense_embedding_stream(n_per, D, seed=seed0 + k, rate=1.0)
        for k in range(K)
    ]
    events = sorted(
        (float(streams[k][1][i]), k, i)
        for k in range(K) for i in range(n_per)
    )
    return streams, events


def _truths(streams, uid_maps):
    """Per-tenant brute-force pair sets, mapped to global uids."""
    out = []
    for k, (v, t) in enumerate(streams):
        local = planted_duplicates(v, t, THETAS[k], LAMS[k])
        out.append({
            (min(uid_maps[k][a], uid_maps[k][b]),
             max(uid_maps[k][a], uid_maps[k][b]))
            for a, b in local
        })
    return out


def _run(streams, events, submit_plan, span=2, flush_every=None, **cfg_kw):
    """Drive one runtime over the interleaved streams.

    ``submit_plan`` groups consecutive events into submit calls (list of
    chunk lengths, cycled); ``flush_every`` interposes non-final flushes —
    together they realize one arbitrary coalescing of the same stream.
    """
    table = TenantTable(THETAS, LAMS)
    rt = MultiTenantRuntime(_cfg(**cfg_kw), table, span=span)
    uid_maps = [dict() for _ in range(K)]
    i, plan_i, n_flush = 0, 0, 0
    while i < len(events):
        n = submit_plan[plan_i % len(submit_plan)]
        plan_i += 1
        chunk = events[i:i + n]
        i += len(chunk)
        # consecutive same-tenant events submit together; others 1-by-1
        j = 0
        while j < len(chunk):
            k = chunk[j][1]
            run = [chunk[j]]
            while j + 1 < len(chunk) and chunk[j + 1][1] == k:
                j += 1
                run.append(chunk[j])
            v, t = streams[k]
            idx = [e[2] for e in run]
            uids = rt.submit(k, v[idx], t[idx])
            for ii, u in zip(idx, uids.tolist()):
                uid_maps[k][ii] = u
            j += 1
        n_flush += 1
        if flush_every and n_flush % flush_every == 0:
            rt.flush()
    rt.flush(final=True)
    per = rt.drain_by_tenant()
    return rt, per, uid_maps


def _pair_sets(per):
    return [
        {(min(a, b), max(a, b))
         for a, b in zip(per[k][0].tolist(), per[k][1].tolist())}
        for k in range(K)
    ]


# --------------------------------------------------------------------- #
# tentpole acceptance: K ≥ 8 interleaved streams, exact per-tenant pair
# sets, invariant to coalescing boundaries
# --------------------------------------------------------------------- #
def test_multi_tenant_exact_and_coalescing_invariant():
    streams, events = _tenant_streams()
    ref_rt, ref_per, ref_maps = _run(streams, events, submit_plan=[1])
    truths = _truths(streams, ref_maps)
    ref_sets = _pair_sets(ref_per)
    for k in range(K):
        assert ref_sets[k] == truths[k], f"tenant {k}"
        # scores clear the tenant's own threshold
        assert (ref_per[k][2] >= THETAS[k] - 1e-6).all(), f"tenant {k}"
    assert ref_rt.pairs_dropped == 0 and ref_rt.overflow == 0

    # arbitrary coalescing splits: chunked submits, interleaved early
    # flushes, different spans and micro-batches — identical emissions.
    # uid assignment follows admission order, which all plans share, so
    # uid maps (and hence mapped pair sets) must agree exactly.
    rng = np.random.default_rng(0)
    rand_plan = rng.integers(1, 40, 50).tolist()
    for plan, flush_every, span, mb in [
        ([7], 3, 2, 32),                  # small uneven submits
        ([160], None, 4, 32),             # big submits, one final flush
        (rand_plan, 2, 1, 32),            # random chunking, eager flushes
        ([13], None, 3, 64),              # different micro-batch size
    ]:
        rt, per, maps = _run(
            streams, events, submit_plan=plan, flush_every=flush_every,
            span=span, micro_batch=mb, block_q=min(mb, 32),
        )
        assert maps == ref_maps
        assert _pair_sets(per) == ref_sets, (plan, flush_every, span, mb)
        assert rt.pairs_dropped == 0


def test_no_cross_stream_pairs_on_identical_streams():
    """Feed every tenant the *same* vectors at the same timestamps: any
    cross-stream leak would pair items across tenants immediately."""
    table = TenantTable.uniform(4, 0.9, 0.05)
    rt = MultiTenantRuntime(_cfg(), table, span=2)
    vecs, ts = dense_embedding_stream(64, D, seed=5, rate=2.0)
    uid_tenant = {}
    for i in range(64):
        for k in range(4):
            u = rt.submit(k, vecs[i:i + 1], ts[i:i + 1])
            uid_tenant[int(u[0])] = k
    rt.flush(final=True)
    ua, ub, _ = rt.drain_arrays()
    assert ua.size > 0        # the planted duplicates do pair within-stream
    for a, b in zip(ua.tolist(), ub.tolist()):
        assert uid_tenant[a] == uid_tenant[b]
    # every tenant sees the same within-stream pair set
    per = rt.drain_by_tenant()   # empty (already drained) — use counters
    truth = planted_duplicates(vecs, ts, 0.9, 0.05)
    assert ua.size == 4 * len(truth)
    assert all(per[k][0].size == 0 for k in range(4))


def test_overflow_counters_sum_exact_per_level():
    """Acceptance: under tight budgets the per-level drop counters still
    sum exactly to the true pair count, and the match mask stays exact."""
    streams, events = _tenant_streams(n_per=40)
    ref_rt, ref_per, maps = _run(streams, events, submit_plan=[9])
    truth_total = sum(len(s) for s in _truths(streams, maps))
    assert ref_rt.pairs_dropped == 0

    for kw in (dict(max_pairs=2), dict(tile_k=1)):
        rt, per, m2 = _run(streams, events, submit_plan=[9], **kw)
        s = rt.stats()
        survivors = sum(per[k][0].size for k in range(K))
        assert s["pairs_emitted"] == survivors
        assert survivors + s["pairs_dropped"] == truth_total, kw
        assert (
            s["pairs_dropped"]
            == s["pairs_dropped_budget"] + s["pairs_dropped_tile"]
        )
        # survivors are a subset of some tenant's truth (never cross-stream)
        truths = _truths(streams, m2)
        for k in range(K):
            got = {(min(a, b), max(a, b))
                   for a, b in zip(per[k][0].tolist(), per[k][1].tolist())}
            assert got <= truths[k]


def test_window_overflow_attributed_per_tenant():
    """Satellite: live-slot overwrites are charged to the *victim* stream
    — ``window_overflow_by_tenant`` sums exactly to ``window_overflow``,
    and ``tenant_stats`` surfaces each tenant's own count instead of the
    old global-only counter."""
    table = TenantTable.uniform(3, 0.9, 0.01)   # τ ≈ 10.5: everything lives
    rt = MultiTenantRuntime(_cfg(capacity=32, micro_batch=32), table, span=1)
    rng = np.random.default_rng(9)

    def vecs(n):
        v = rng.standard_normal((n, D)).astype(np.float32)
        return v / np.linalg.norm(v, axis=1, keepdims=True)

    # fill the 32-slot ring: 16 items of tenant 1, 16 of tenant 2
    rt.submit(1, vecs(16), np.linspace(0.0, 0.15, 16))
    rt.submit(2, vecs(16), np.linspace(0.2, 0.35, 16))
    rt.flush()
    assert rt.stats()["window_overflow"] == 0
    # tenant 0 floods 32 more: every write overwrites a live victim
    rt.submit(0, vecs(32), np.linspace(0.4, 0.7, 32))
    rt.flush()
    s = rt.stats()
    assert s["window_overflow"] == 32
    assert s["window_overflow_by_tenant"] == [0, 16, 16]
    assert sum(s["window_overflow_by_tenant"]) == s["window_overflow"]
    for t, want in enumerate([0, 16, 16]):
        assert rt.tenant_stats(t)["window_overflow"] == want
    # and the perpetrator's next flood evicts only itself
    rt.submit(0, vecs(32), np.linspace(0.8, 1.1, 32))
    rt.flush()
    s = rt.stats()
    assert s["window_overflow"] == 64
    assert s["window_overflow_by_tenant"] == [32, 16, 16]


def test_quota_runtime_validation():
    """Quota plumbing: the table length must match the tenant count, and
    tenant_stats reports each tenant's slot quota."""
    table = TenantTable.uniform(2, 0.9, 0.1)
    with pytest.raises(ValueError):
        MultiTenantRuntime(
            _cfg(eviction="quota", quotas=(256, 256, 512)), table
        )
    with pytest.raises(ValueError):                 # sum != capacity
        _cfg(eviction="quota", quotas=(100, 100))
    with pytest.raises(ValueError):                 # quotas without policy
        _cfg(quotas=(512, 512))
    rt = MultiTenantRuntime(_cfg(eviction="quota", quotas=(256, 768)), table)
    assert rt.tenant_stats(0)["quota"] == 256
    assert rt.tenant_stats(1)["quota"] == 768
    assert rt.stats()["eviction"] == "quota"


def test_match_masks_ride_per_tenant():
    streams, events = _tenant_streams(n_per=48)
    table = TenantTable(THETAS, LAMS)
    rt = MultiTenantRuntime(_cfg(), table, span=2)
    uid_maps = [dict() for _ in range(K)]
    for _, k, i in events:
        v, t = streams[k]
        u = rt.submit(k, v[i:i + 1], t[i:i + 1])
        uid_maps[k][i] = int(u[0])
    rt.flush(final=True)
    per = rt.drain_by_tenant(return_masks=True)
    truths = _truths(streams, uid_maps)
    for k in range(K):
        ua, ub, sc, mask = per[k]
        assert mask.shape[0] == 48
        # the mask marks the newer side of each pair, in this tenant's
        # admission order
        order = sorted(uid_maps[k].values())
        newer = {max(a, b) for a, b in truths[k]}
        want = np.array([u in newer for u in order])
        np.testing.assert_array_equal(mask, want, err_msg=f"tenant {k}")


# --------------------------------------------------------------------- #
# property-based router contracts (hypothesis when present, fixed sweep
# otherwise — same pattern as test_compaction.py)
# --------------------------------------------------------------------- #
def _check_router_schedule(seed, n_tenants, cap):
    """Arbitrary admit/take schedule vs a shadow FIFO model: admission
    order is the only order, backpressure is all-or-nothing, and the
    accounting identities hold after every operation."""
    rng = np.random.default_rng(seed)
    router = RequestRouter(n_tenants, max_queue_per_tenant=cap)
    shadow = []                      # (tenant, uid) in admission order
    next_uid = 0
    admitted = rejected = dispatched = 0
    for _ in range(60):
        if shadow and rng.random() < 0.4:
            n = int(rng.integers(1, len(shadow) + 1))
            _, ts, uids, sids, _ = router.take(n)
            want = shadow[:n]
            del shadow[:n]
            dispatched += n
            assert uids.tolist() == [u for _, u in want]      # exact order
            assert sids.tolist() == [t for t, _ in want]
        else:
            t = int(rng.integers(0, n_tenants))
            b = int(rng.integers(1, 12))
            payload = np.zeros((b, 4), np.float32)
            uids = np.arange(next_uid, next_uid + b, dtype=np.int32)
            queued_t = router.queued_by_tenant[t]
            before = [router.queued_by_tenant[k] for k in range(n_tenants)]
            if queued_t + b > cap:
                with pytest.raises(TenantBackpressure):
                    router.admit(t, payload, np.zeros(b), uids)
                # all-or-nothing: nothing enqueued, nothing counted admitted
                rejected += b
                assert [router.queued_by_tenant[k]
                        for k in range(n_tenants)] == before
                assert len(router) == len(shadow)
            else:
                router.admit(t, payload, np.zeros(b), uids)
                shadow.extend((t, int(u)) for u in uids)
                next_uid += b
                admitted += b
        # accounting identities, after every operation
        tel = router.telemetry
        assert tel.items_admitted == admitted
        assert tel.items_rejected == rejected
        assert tel.items_dispatched == dispatched
        assert len(router) == len(shadow) == admitted - dispatched
        for k in range(n_tenants):
            assert router.queued_by_tenant[k] == sum(
                1 for t, _ in shadow if t == k
            )
        assert tel.queue_delay_sum_s >= 0.0


@pytest.mark.parametrize("seed,cap", [(0, 16), (1, 8), (2, 31), (3, 1)])
def test_router_schedule_sweep(seed, cap):
    _check_router_schedule(seed, n_tenants=3, cap=cap)


def _check_coalescing_invariance(seed, span, flush_every, streams, events,
                                 ref_maps, ref_sets):
    """One arbitrary coalescing of the same admitted traffic must emit the
    identical per-tenant pair sets (uids assign at admission, which every
    plan shares)."""
    rng = np.random.default_rng(seed)
    plan = rng.integers(1, 48, 24).tolist()
    rt, per, maps = _run(
        streams, events, submit_plan=plan, flush_every=flush_every, span=span,
    )
    assert maps == ref_maps
    assert _pair_sets(per) == ref_sets, (seed, span, flush_every)
    assert rt.pairs_dropped == 0


_PROP_CACHE = {}


def _prop_reference():
    """Reference emission for the property runs (computed once)."""
    if "ref" not in _PROP_CACHE:
        streams, events = _tenant_streams(n_per=40)
        _, per, maps = _run(streams, events, submit_plan=[1])
        _PROP_CACHE["ref"] = (streams, events, maps, _pair_sets(per))
    return _PROP_CACHE["ref"]


@pytest.mark.parametrize("seed,span,flush_every", [
    (0, 2, None), (1, 1, 1), (2, 3, 2),
])
def test_coalescing_invariance_sweep(seed, span, flush_every):
    streams, events, ref_maps, ref_sets = _prop_reference()
    _check_coalescing_invariance(
        seed, span, flush_every, streams, events, ref_maps, ref_sets
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cap=st.integers(1, 64),
        n_tenants=st.integers(1, 5),
    )
    def test_router_schedule_property(seed, cap, n_tenants):
        _check_router_schedule(seed, n_tenants=n_tenants, cap=cap)

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        span=st.integers(1, 3),
        flush_every=st.sampled_from([None, 1, 2, 3]),
    )
    def test_coalescing_invariance_property(seed, span, flush_every):
        streams, events, ref_maps, ref_sets = _prop_reference()
        _check_coalescing_invariance(
            seed, span, flush_every, streams, events, ref_maps, ref_sets
        )


# --------------------------------------------------------------------- #
# router: backpressure, telemetry, validation
# --------------------------------------------------------------------- #
def test_backpressure_is_all_or_nothing():
    table = TenantTable.uniform(2, 0.9, 0.1)
    rt = MultiTenantRuntime(_cfg(), table, max_queue_per_tenant=10)
    vecs, ts = dense_embedding_stream(16, D, seed=1)
    rt.submit(0, vecs[:8], ts[:8])
    with pytest.raises(TenantBackpressure):
        rt.submit(0, vecs[8:12], ts[8:12])      # 8 + 4 > 10
    # nothing from the failed submit was admitted; tenant 1 is unaffected
    assert rt.stats()["items_queued"] == 8
    assert rt.stats()["items_rejected"] == 4
    rt.submit(1, vecs[8:], ts[8:])
    rt.submit(0, vecs[8:10], ts[8:10])          # exactly at the cap
    rt.flush(final=True)
    assert rt.n_items == 18


def test_padding_telemetry_counts_waste():
    table = TenantTable.uniform(2, 0.9, 0.1)
    rt = MultiTenantRuntime(_cfg(micro_batch=32), table, span=2)
    vecs, ts = dense_embedding_stream(40, D, seed=2)
    rt.submit(0, vecs, ts)
    rt.flush(final=True)     # 40 rows → 2 micro-batches (64) in one span
    s = rt.stats()
    assert s["n_items"] == 40
    assert s["padded_rows"] == 2 * 32 - 40
    assert 0.0 < s["padding_waste"] < 1.0
    assert s["queue_delay_max_s"] >= 0.0


def test_tenant_table_validation():
    with pytest.raises(ValueError):
        TenantTable([], [])
    with pytest.raises(ValueError):
        TenantTable([0.5, 1.5], [0.1, 0.1])
    with pytest.raises(ValueError):
        TenantTable([0.5], [-0.1])
    with pytest.raises(ValueError):
        TenantTable([0.5, 0.6], [0.1])
    t = TenantTable([0.5, 0.6], [0.1, 0.2])
    assert not t.is_uniform and t.n_tenants == 2
    assert TenantTable.uniform(3, 0.9, 0.1).is_uniform
    with pytest.raises(ValueError):
        t.validate_id(2)
    rt = MultiTenantRuntime(_cfg(), TenantTable.uniform(2, 0.9, 0.1))
    with pytest.raises(ValueError):
        rt.submit(0, np.zeros((2, D + 1), np.float32), np.zeros(2))
    with pytest.raises(NotImplementedError):
        rt.push(np.zeros((1, D), np.float32), np.zeros(1))


def test_engine_config_validation():
    """Satellite: misconfigurations fail at construction with clear
    messages, not as downstream shape errors inside the jitted scan."""
    ok = _cfg()
    assert ok.micro_batch <= ok.capacity
    cases = [
        dict(micro_batch=2048),            # micro_batch > capacity
        dict(max_pairs=0),
        dict(tile_k=-1),
        dict(micro_batch=0),
        dict(capacity=0),
        dict(d=0),
        dict(use_ref=True, join_impl="pallas"),   # impl contradiction
        dict(theta=0.0),
        dict(theta=1.5),
        dict(lam=-0.1),
        dict(join_impl="nope"),
        dict(shard_k=0),
        dict(chunk_d=0),
    ]
    for kw in cases:
        with pytest.raises(ValueError):
            _cfg(**kw)


# --------------------------------------------------------------------- #
# fused embed→join
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def embedder():
    import jax
    from repro.configs import ARCHS
    from repro.serving.embedder import LMEmbedder
    return LMEmbedder(ARCHS["qwen3-0.6b"].reduced(), key=jax.random.key(0))


def test_fused_embed_join_bit_identical_to_host_roundtrip(embedder):
    """Satellite acceptance: embedding inside the join scan must emit the
    exact same pairs, scores, and masks as embedding on the host and
    pushing vectors — bit-identical, same pooled-embed function either
    way."""
    from repro.runtime import FusedEmbedder

    S, n = 32, 56
    rng = np.random.default_rng(7)
    toks = rng.integers(1, 500, (n, S)).astype(np.int32)
    tenants = rng.integers(0, 3, n)
    # plant near-duplicates within tenant 1
    plant = np.where(tenants == 1)[0][:4]
    for i in plant[1:]:
        toks[i] = toks[plant[0]]
    ts = np.cumsum(rng.exponential(0.05, n))

    table = TenantTable([0.9, 0.85, 0.9], [0.1, 0.05, 0.1])
    cfg = _cfg(capacity=256, micro_batch=16, block_q=16, block_w=16,
               chunk_d=64)
    fused = FusedEmbedder(embedder.cfg, embedder.params, S)
    rt_f = MultiTenantRuntime(cfg, table, span=2, fused=fused)
    rt_h = MultiTenantRuntime(cfg, table, span=2)
    for i in range(n):
        k = int(tenants[i])
        uf = rt_f.submit(k, toks[i:i + 1], ts[i:i + 1])
        uh = rt_h.submit(k, embedder(toks[i:i + 1]), ts[i:i + 1])
        assert uf.tolist() == uh.tolist()
    rt_f.flush(final=True)
    rt_h.flush(final=True)
    fa, fb, fs, fm = rt_f.drain_arrays(return_masks=True)
    ha, hb, hs, hm = rt_h.drain_arrays(return_masks=True)
    assert fa.size > 0                       # the planted dups did emit
    np.testing.assert_array_equal(fa, ha)
    np.testing.assert_array_equal(fb, hb)
    np.testing.assert_array_equal(fs, hs)    # bit-identical scores
    np.testing.assert_array_equal(fm, hm)


def test_fused_embedder_validation(embedder):
    from repro.runtime import FusedEmbedder

    table = TenantTable.uniform(2, 0.9, 0.1)
    with pytest.raises(ValueError):          # d_model (64) != cfg.d (32)
        MultiTenantRuntime(
            _cfg(d=32), table, fused=FusedEmbedder(embedder.cfg, embedder.params, 16)
        )
    rt = MultiTenantRuntime(
        _cfg(capacity=256, micro_batch=16, block_q=16),
        table, fused=FusedEmbedder(embedder.cfg, embedder.params, 16),
    )
    with pytest.raises(ValueError):          # wrong token width
        rt.submit(0, np.zeros((2, 8), np.int32), np.zeros(2))


# --------------------------------------------------------------------- #
# multi-tenant service: namespaced union-find, per-tenant groups
# --------------------------------------------------------------------- #
def test_multi_tenant_service_namespaced_groups():
    from repro.serving import MultiTenantSSSJService

    rng = np.random.default_rng(11)
    table = TenantTable([0.9, 0.9, 0.95], [0.05, 0.05, 0.02])
    svc = MultiTenantSSSJService(table, dim=32, capacity=256, micro_batch=16)
    base = rng.standard_normal(32).astype(np.float32)
    t = 0.0
    for _ in range(4):
        for k in range(3):
            b = rng.standard_normal((4, 32)).astype(np.float32)
            b[0] = base + 0.01 * rng.standard_normal(32)
            local = svc.submit(k, b, t + np.arange(4) * 0.01)
            assert local.tolist() == list(range(local[0], local[0] + 4))
        t += 0.2
    svc.flush(final=True)
    for k in range(3):
        groups = svc.duplicate_groups(k)
        # each tenant groups its own planted copies, under LOCAL uids —
        # identical group structure across tenants, no cross-tenant merge
        assert groups == [[0, 4, 8, 12]], f"tenant {k}"
        assert svc.trending(k, min_size=4) == [[0, 4, 8, 12]]
        assert svc.tenant_stats(k)["submitted"] == 16
