"""Device-resident engine: compacted emission vs the dense oracle,
overflow contracts, scan-carry determinism, and the sharded fan-out."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.synth import dense_embedding_stream, planted_duplicates
from repro.engine import EngineConfig, StreamEngine
from repro.kernels.sssj_join import compact_pairs, sssj_join_tiles

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(theta=0.8, lam=0.05, d=64, **kw):
    base = dict(theta=theta, lam=lam, capacity=512, d=d, micro_batch=32,
                max_pairs=1024, block_q=32, block_w=32, chunk_d=32)
    base.update(kw)
    return EngineConfig(**base)


def _pair_set(ua, ub):
    return set((min(a, b), max(a, b)) for a, b in zip(ua.tolist(), ub.tolist()))


# --------------------------------------------------------------------- #
# compacted emission == dense np.nonzero extraction
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("theta,lam", [(0.8, 0.05), (0.6, 0.2), (0.95, 0.02)])
def test_engine_matches_dense_oracle(theta, lam):
    d = 64
    vecs, ts = dense_embedding_stream(320, d, seed=7, rate=2.0)
    truth = planted_duplicates(vecs, ts, theta, lam)
    eng = StreamEngine(_cfg(theta=theta, lam=lam, d=d))
    for i in range(0, 320, 80):          # 80 = 2.5 micro-batches → padding
        eng.push(vecs[i:i + 80], ts[i:i + 80])
    ua, ub, sc = eng.drain_arrays()
    assert _pair_set(ua, ub) == truth
    assert (sc >= theta).all()
    assert eng.pairs_dropped == 0
    assert eng.overflow == 0


def test_compaction_matches_nonzero_extraction(rng):
    """compact_pairs must reproduce np.nonzero over the dense score matrix
    exactly: same pairs, same scores."""
    Q, W, d = 96, 64, 64
    q = rng.standard_normal((Q, d)).astype(np.float32)
    q[: Q // 4] = q[Q // 4: Q // 2] + 0.02  # plant some matches
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    tq = np.sort(rng.random(Q)).astype(np.float32)
    uq = np.arange(100, 100 + Q, dtype=np.int32)
    w = q[:W]
    tw = tq[:W]
    uw = np.arange(W, dtype=np.int32)
    scores, _, counts = sssj_join_tiles(
        jnp.asarray(q), jnp.asarray(w), jnp.asarray(tq), jnp.asarray(tw),
        jnp.asarray(uq), jnp.asarray(uw),
        theta=0.5, lam=0.1, block_q=32, block_w=32, chunk_d=32,
    )
    buf = compact_pairs(scores, jnp.asarray(uq), jnp.asarray(uw), max_pairs=512)
    n = int(buf.n_pairs)
    s_np = np.asarray(scores)
    qi, wi = np.nonzero(s_np)
    assert n == qi.size and int(buf.n_dropped) == 0
    # kernel per-tile counts (compaction stage 1) agree with the dense matrix
    assert int(np.asarray(counts).sum()) == qi.size
    got = {
        (int(a), int(b)): float(s)
        for a, b, s in zip(
            np.asarray(buf.uid_a)[:n], np.asarray(buf.uid_b)[:n],
            np.asarray(buf.score)[:n],
        )
    }
    want = {(int(uq[a]), int(uw[b])): float(s_np[a, b]) for a, b in zip(qi, wi)}
    assert got.keys() == want.keys()
    for k in want:
        assert abs(got[k] - want[k]) < 1e-6
    # buffer tail is inert
    assert (np.asarray(buf.uid_a)[n:] == -1).all()
    assert (np.asarray(buf.score)[n:] == 0.0).all()


def test_engine_emission_paths_agree():
    """The default hierarchical path (both its compiled-scan and Pallas
    level-1 implementations) and the emit_dense oracle path must emit the
    identical pair set, scores, and match masks end to end."""
    d = 64
    vecs, ts = dense_embedding_stream(192, d, seed=11, rate=2.0)

    def run(**kw):
        eng = StreamEngine(_cfg(d=d, **kw))
        for i in range(0, 192, 80):
            eng.push(vecs[i:i + 80], ts[i:i + 80])
        ua, ub, sc, mask = eng.drain_arrays(return_masks=True)
        assert eng.pairs_dropped == 0
        return dict(zip(zip(ua.tolist(), ub.tolist()), sc.tolist())), mask

    ref_pairs, ref_mask = run(emit_dense=True)
    for kw in [dict(), dict(join_impl="pallas"), dict(use_ref=True)]:
        pairs, mask = run(**kw)
        assert pairs.keys() == ref_pairs.keys(), kw
        np.testing.assert_allclose(
            [pairs[k] for k in ref_pairs], list(ref_pairs.values()),
            atol=1e-5,
        )
        np.testing.assert_array_equal(mask, ref_mask)


# --------------------------------------------------------------------- #
# overflow contracts
# --------------------------------------------------------------------- #
def _dense_cluster(d=32, n=64, seed=1):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(d).astype(np.float32)
    vecs = base + 0.01 * rng.standard_normal((n, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    ts = np.linspace(0.0, 0.01, n)       # everything similar & recent
    return vecs, ts


@pytest.mark.parametrize(
    "small_kw,level",
    [
        (dict(max_pairs=16, tile_k=1024), "budget"),   # only max_pairs can drop
        (dict(max_pairs=4096, tile_k=8), "tile"),      # only tile_k can drop
    ],
)
def test_emission_overflow_flags(small_kw, level):
    """When a micro-batch emits more than an emission capacity allows —
    the global max_pairs budget or a level-1 tile_k candidate buffer —
    the engine must keep a prefix, attribute every loss to its level, and
    keep the window state exact (no corruption of later batches)."""
    d = 32
    vecs, ts = _dense_cluster(d=d)
    # tile_k = block² (1024) makes level 1 lossless; max_pairs=4096 covers
    # everything a 32-item micro-batch can emit against this window
    small = StreamEngine(_cfg(theta=0.9, lam=0.01, d=d, **small_kw))
    big = StreamEngine(_cfg(theta=0.9, lam=0.01, d=d, max_pairs=4096,
                            tile_k=1024))
    for i in range(0, 64, 32):
        small.push(vecs[i:i + 32], ts[i:i + 32])
        big.push(vecs[i:i + 32], ts[i:i + 32])
    ua_s, ub_s, _, mask = small.drain_arrays(return_masks=True)
    ua_b, ub_b, _ = big.drain_arrays()
    assert big.pairs_dropped == 0
    assert small.pairs_dropped > 0
    # drops are attributed to the right level, and nothing is double-counted
    s = small.stats()
    assert s["pairs_dropped"] == s["pairs_dropped_budget"] + s["pairs_dropped_tile"]
    if level == "budget":
        assert s["pairs_dropped_tile"] == 0 and s["pairs_dropped_budget"] > 0
    else:
        assert s["pairs_dropped_budget"] == 0 and s["pairs_dropped_tile"] > 0
    assert ua_s.size + small.pairs_dropped == ua_b.size
    # the survivors are a subset of the true pair set
    assert _pair_set(ua_s, ub_s) <= _pair_set(ua_b, ub_b)
    # the per-row match mask is exact even under emission overflow
    matched = np.zeros(64, bool)
    matched[np.asarray(ua_b)] = True     # uid_a is the newer (query) side
    np.testing.assert_array_equal(mask, matched)


def test_ring_overflow_counter():
    """Overwriting still-live items must be counted (window undersized)."""
    d = 32
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((128, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    ts = np.linspace(0.0, 0.1, 128)
    eng = StreamEngine(_cfg(theta=0.9, lam=0.001, d=d, capacity=64))
    for i in range(0, 128, 32):
        eng.push(vecs[i:i + 32], ts[i:i + 32])
    assert eng.overflow > 0


# --------------------------------------------------------------------- #
# scan-carry determinism
# --------------------------------------------------------------------- #
def test_scan_carry_determinism():
    """The emitted pair set and the final window state must not depend on
    how the stream is split into push calls or micro-batches."""
    d = 64
    vecs, ts = dense_embedding_stream(192, d, seed=13, rate=2.0)

    def run(push_sizes, micro_batch):
        eng = StreamEngine(_cfg(d=d, micro_batch=micro_batch))
        i = 0
        for b in push_sizes:
            eng.push(vecs[i:i + b], ts[i:i + b])
            i += b
        assert i == 192
        ua, ub, sc = eng.drain_arrays()
        pairs = set(zip(ua.tolist(), ub.tolist(), np.round(sc, 5).tolist()))
        return pairs, eng.state

    ref_pairs, ref_state = run([192], 32)
    for split, mb in [
        ([64] * 3, 32),
        ([50, 50, 50, 42], 32),          # pad every push
        ([192], 16),                     # finer micro-batches
        ([33] * 5 + [27], 16),
    ]:
        pairs, state = run(split, mb)
        assert pairs == ref_pairs, (split, mb)
        np.testing.assert_array_equal(np.asarray(state.uids),
                                      np.asarray(ref_state.uids))
        np.testing.assert_array_equal(np.asarray(state.ts),
                                      np.asarray(ref_state.ts))
        np.testing.assert_allclose(np.asarray(state.vecs),
                                   np.asarray(ref_state.vecs))
        assert int(state.cursor) == int(ref_state.cursor)


# --------------------------------------------------------------------- #
# telemetry
# --------------------------------------------------------------------- #
def test_engine_telemetry_and_bytes():
    d = 64
    vecs, ts = dense_embedding_stream(128, d, seed=5, rate=2.0)
    eng = StreamEngine(_cfg(d=d))
    eng.push(vecs, ts)
    ua, _, _ = eng.drain_arrays()
    s = eng.stats()
    assert s["n_items"] == 128
    assert s["tiles_total"] > 0
    # in-carry emit counter agrees with what the drain actually delivered
    assert s["pairs_emitted"] == ua.shape[0]
    assert s["pairs_dropped"] == 0
    # compacted drain must move less than the dense matrices would have
    assert 0 < s["bytes_to_host"] < s["bytes_dense_equiv"]


# --------------------------------------------------------------------- #
# sharded fan-out (8 forced host devices; subprocess keeps the main
# process on 1 device — see test_distributed.py)
# --------------------------------------------------------------------- #
def test_sharded_engine_matches_oracle():
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.data.synth import dense_embedding_stream, planted_duplicates
        from repro.engine import EngineConfig, ShardedStreamEngine
        theta, lam, d = 0.8, 0.05, 64
        vecs, ts = dense_embedding_stream(256, d, seed=3, rate=2.0)
        truth = planted_duplicates(vecs, ts, theta, lam)
        mesh = jax.make_mesh((8,), ("data",))
        cfg = EngineConfig(theta=theta, lam=lam, capacity=64, d=d,
                           micro_batch=32, max_pairs=512,
                           block_q=32, block_w=32, chunk_d=32)
        eng = ShardedStreamEngine(cfg, mesh)
        for i in range(0, 256, 80):      # ragged pushes → padding path too
            eng.push(vecs[i:i+80], ts[i:i+80])
        ua, ub, sc, mask = eng.drain_arrays(return_masks=True)
        got = set((min(a, b), max(a, b)) for a, b in zip(ua.tolist(), ub.tolist()))
        assert got == truth, (len(got), len(truth))
        assert (sc >= theta).all()
        assert eng.pairs_dropped == 0
        s = eng.stats()
        assert s["n_shards"] == 8 and s["n_items"] == 256
        # the gathered match mask marks exactly the newer sides
        want = np.zeros(256, bool); want[np.asarray(ua)] = True
        np.testing.assert_array_equal(mask, want)

        # max_pairs is a GLOBAL budget with exact per-level drop attribution:
        # survivors + drops == truth even under a tight budget / shard cap
        for kw in (dict(max_pairs=2), dict(max_pairs=512, shard_k=1),
                   dict(max_pairs=512, tile_k=1)):
            cfg2 = EngineConfig(theta=theta, lam=lam, capacity=64, d=d,
                                micro_batch=32, block_q=32, block_w=32,
                                chunk_d=32, **kw)
            e2 = ShardedStreamEngine(cfg2, mesh)
            for i in range(0, 256, 80):
                e2.push(vecs[i:i+80], ts[i:i+80])
            ua2, ub2, _, mask2 = e2.drain_arrays(return_masks=True)
            s2 = e2.stats()
            assert s2["pairs_emitted"] == ua2.size
            assert ua2.size + s2["pairs_dropped"] == len(truth), (kw, ua2.size)
            got2 = set((min(a, b), max(a, b))
                       for a, b in zip(ua2.tolist(), ub2.tolist()))
            assert got2 <= truth
            np.testing.assert_array_equal(mask2, want)  # mask exact under drops
        print("sharded engine exact:", len(got))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "sharded engine exact:" in r.stdout
