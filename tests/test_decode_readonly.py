"""Unit tests for the perf-D4 decode path: read-only cache attention must
equal the materialized decode branch, and the stacked append must place
tokens correctly."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.attention import (
    AttnCache, attention, attention_decode_readonly, init_attention,
)
from repro.models.common import Initializer
from repro.models.lm import _append_tokens


def test_readonly_matches_materialized_decode(rng):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    params, _ = init_attention(Initializer(jax.random.key(0)), cfg)
    B, M, L = 2, 16, 3
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    cache_len = 7
    k = jnp.asarray(rng.standard_normal((B, M, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, M, kv, hd)), jnp.float32)
    # zero out positions >= cache_len (as a real cache would have)
    mask = (jnp.arange(M) < cache_len)[None, :, None, None]
    cache = AttnCache(k=k * mask, v=v * mask)
    x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
    pos = jnp.full((B, 1), cache_len, jnp.int32)

    # reference: the materialized decode branch (writes the token, attends)
    y_ref, _ = attention(params, cfg, x, pos, cache=cache,
                         cache_len=jnp.int32(cache_len))
    # read-only two-segment path
    y_ro, k_new, v_new = attention_decode_readonly(
        params, cfg, x, pos, cache, jnp.int32(cache_len)
    )
    np.testing.assert_allclose(np.asarray(y_ro), np.asarray(y_ref), atol=2e-5)
    assert k_new.shape == (B, 1, kv, hd)


def test_append_tokens_places_all_layers():
    L, B, M, KV, hd = 3, 2, 8, 2, 4
    cache = AttnCache(
        k=jnp.zeros((L, B, M, KV, hd)), v=jnp.zeros((L, B, M, KV, hd))
    )
    news = (
        jnp.arange(L * B * KV * hd, dtype=jnp.float32).reshape(L, B, 1, KV, hd),
        -jnp.ones((L, B, 1, KV, hd)),
    )
    out = _append_tokens(cache, news, jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(out.k[:, :, 5]),
                                  np.asarray(news[0][:, :, 0]))
    assert float(out.k[:, :, :5].sum()) == 0.0
    assert float(out.v[:, :, 5].sum()) == -L * B * KV * hd
