"""Serving: embedder produces unit vectors; service finds planted
near-duplicates and trends."""

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.serving.embedder import LMEmbedder
from repro.serving.service import SSSJService


@pytest.fixture(scope="module")
def embedder():
    return LMEmbedder(ARCHS["qwen3-0.6b"].reduced(), key=jax.random.key(0))


def test_embedder_unit_norm(embedder, rng):
    toks = rng.integers(1, 500, (4, 32)).astype(np.int32)
    e = embedder(toks)
    assert e.shape == (4, 64)
    np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, atol=1e-5)


def test_embedder_near_dup_similarity(embedder):
    """Averaged over trials, near-duplicates embed closer than unrelated
    documents (an untrained reduced model is noisy per-instance)."""
    r = np.random.default_rng(42)
    near_sims, far_sims = [], []
    for _ in range(8):
        base = r.integers(1, 500, (1, 64)).astype(np.int32)
        near = base.copy()
        near[0, -2:] = r.integers(1, 500, 2)
        far = r.integers(1, 500, (1, 64)).astype(np.int32)
        e = embedder(np.concatenate([base, near, far]))
        near_sims.append(float(e[0] @ e[1]))
        far_sims.append(float(e[0] @ e[2]))
    assert np.mean(near_sims) > np.mean(far_sims)


def test_service_end_to_end(embedder, rng):
    service = SSSJService(theta=0.9, lam=0.1, dim=64, embed_fn=embedder)
    base = rng.integers(1, 500, (64,)).astype(np.int32)
    batches = []
    for r in range(4):
        b = rng.integers(1, 500, (8, 64)).astype(np.int32)
        b[0] = base          # plant one copy per request batch
        batches.append(b)
    t = 0.0
    for b in batches:
        service.submit(b, t + np.arange(8) * 0.01)
        t += 0.5
    groups = service.duplicate_groups()
    assert groups, "planted duplicates not found"
    planted_uids = {r * 8 for r in range(4)}
    big = max(groups, key=len)
    assert planted_uids.issubset(set(big))
    trends = service.trending(min_size=3)
    assert trends and set(big) in [set(t_) for t_ in trends]


def test_service_respects_horizon():
    service = SSSJService(theta=0.9, lam=1.0, dim=32)   # τ = log(1/.9) ≈ 0.105
    v = np.ones((1, 32), np.float32)
    service.submit(v, np.array([0.0]))
    pairs = service.submit(v, np.array([10.0]))         # far outside horizon
    assert pairs == []
    pairs = service.submit(v, np.array([10.01]))        # inside
    assert len(pairs) == 1
