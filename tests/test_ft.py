"""Fault tolerance: checkpoint atomicity/roundtrip/resharding, manager
retention + async, health tracking, elastic planning, exact train resume."""

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ft.checkpoint import list_checkpoints, restore_checkpoint, save_checkpoint
from repro.ft.health import ElasticPlanner, HeartbeatTracker
from repro.ft.manager import CheckpointManager


def _state(rng):
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(16), jnp.float32)},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path, rng):
    st = _state(rng)
    p = save_checkpoint(tmp_path, 7, st, extra={"pipeline": {"seed": 1, "step": 7}})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    out, extra, step = restore_checkpoint(p, like)
    assert step == 7 and extra["pipeline"]["step"] == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_partial_dirs(tmp_path, rng):
    save_checkpoint(tmp_path, 1, _state(rng))
    save_checkpoint(tmp_path, 2, _state(rng))
    names = [p.name for p in list_checkpoints(tmp_path)]
    assert names == ["step_00000001", "step_00000002"]
    # every listed checkpoint has a complete manifest
    for p in list_checkpoints(tmp_path):
        man = json.loads((p / "MANIFEST.json").read_text())
        for leaf in man["leaves"]:
            assert (p / leaf["file"]).exists()


def test_shape_mismatch_rejected(tmp_path, rng):
    st = _state(rng)
    p = save_checkpoint(tmp_path, 3, st)
    bad = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                      "b": jax.ShapeDtypeStruct((16,), jnp.float32)},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError):
        restore_checkpoint(p, bad)


def test_manager_retention_and_async(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(rng))
    mgr.wait()
    names = [p.name for p in list_checkpoints(tmp_path)]
    assert names == ["step_00000003", "step_00000004"]
    st = _state(rng)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    out = mgr.restore_latest(like)
    assert out is not None and out[2] == 4


def test_resharding_roundtrip(tmp_path, rng):
    """Save replicated, restore with an explicit (trivial) sharding — the
    mechanism elastic restart uses; multi-device resharding is covered by
    the subprocess test in test_distributed.py."""
    st = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    p = save_checkpoint(tmp_path, 1, st)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    out, _, _ = restore_checkpoint(p, like, shardings=sh)
    assert out["w"].sharding == sh["w"]


def test_exact_training_resume(tmp_path):
    """Interrupt + resume reproduces the uninterrupted run: the data
    pipeline resumes exactly (same batches — bit-identical, tested in
    test_data.py) and the state round-trips losslessly (f32); trajectories
    may drift at bf16-compute scale only (XLA re-chooses output layouts
    after a restore, changing accumulation order)."""
    from repro.launch.train import run_training

    _, hist_full = run_training(
        "qwen3-0.6b", smoke=True, steps=8, batch=2, seq=16,
        ckpt_dir=None, log_every=100,
    )
    d1 = str(tmp_path / "ckpt")
    _, hist_head = run_training(
        "qwen3-0.6b", smoke=True, steps=4, batch=2, seq=16,
        ckpt_dir=d1, ckpt_every=4, log_every=100)
    # pre-interrupt segment is bit-identical
    np.testing.assert_array_equal(hist_full[:4], hist_head)
    _, hist_resumed = run_training(
        "qwen3-0.6b", smoke=True, steps=8, batch=2, seq=16,
        ckpt_dir=d1, ckpt_every=100, log_every=100,
    )
    assert len(hist_resumed) == 4          # resumed from step 4, not 0
    np.testing.assert_allclose(hist_full[4:], hist_resumed, rtol=1e-2)


# --------------------------------------------------------------------- #
# health / elastic
# --------------------------------------------------------------------- #
def test_heartbeat_dead_detection():
    h = HeartbeatTracker(dead_after_s=10.0)
    h.record("w0", 5, 100.0)
    h.record("w1", 5, 105.0)
    assert h.dead(now=112.0) == ["w0"]
    assert h.dead(now=106.0) == []


def test_straggler_p99_rule():
    h = HeartbeatTracker(dead_after_s=1e9, lag_factor=3.0)
    for i in range(20):
        h.record(f"w{i:02d}", 100, 0.0)
    h.record("w20", 50, 0.0)   # 50 steps behind a tight fleet
    assert h.stragglers(now=1.0) == ["w20"]
    # a uniformly slow fleet has no stragglers
    h2 = HeartbeatTracker()
    for i in range(10):
        h2.record(f"w{i}", 10, 0.0)
    assert h2.stragglers(now=1.0) == []


def test_elastic_planner():
    p = ElasticPlanner(chips_per_host=4, model_axis=16, data_axis=16)
    full = p.plan(alive_hosts=128)          # 512 chips = 2 pods
    assert full.shape == (2, 16, 16) and full.hosts_dropped == 0
    one = p.plan(alive_hosts=100)           # 400 chips → 1 pod, drop rest
    assert one.shape == (16, 16) and one.hosts_used == 64
    small = p.plan(alive_hosts=20)          # 80 chips → (4, 16) mesh
    assert small.shape == (4, 16)
    assert p.plan(alive_hosts=0) is None
