"""Pallas kernels vs pure-jnp oracles: shape/dtype/parameter sweeps in
interpret mode (kernel bodies execute on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.sssj_join import sssj_join_scores, suffix_chunk_norms
from repro.kernels.sssj_join.ref import sssj_join_ref


def _unit_rows(rng, n, d, dtype):
    x = rng.standard_normal((n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return jnp.asarray(x, dtype)


# --------------------------------------------------------------------- #
# sssj_join
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("q_n,w_n,d", [(32, 32, 64), (64, 96, 160),
                                       (17, 43, 100), (128, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sssj_kernel_shapes(q_n, w_n, d, dtype, rng):
    q = _unit_rows(rng, q_n, d, dtype)
    w = _unit_rows(rng, w_n, d, dtype)
    tq = jnp.asarray(np.sort(rng.random(q_n) * 20).astype(np.float32)) + 10.0
    tw = jnp.asarray(np.sort(rng.random(w_n) * 20).astype(np.float32))
    uq = jnp.arange(1000, 1000 + q_n, dtype=jnp.int32)
    uw_np = np.arange(w_n, dtype=np.int32)
    uw_np[::5] = -1                       # empty ring slots
    uw = jnp.asarray(uw_np)
    kw = dict(theta=0.4, lam=0.05, block_q=32, block_w=32, chunk_d=32)
    s_kern, iters = sssj_join_scores(q, w, tq, tw, uq, uw, **kw)
    s_ref = sssj_join_ref(
        q, w, tq.reshape(-1, 1), tw.reshape(-1, 1),
        uq.reshape(-1, 1), uw.reshape(-1, 1), theta=0.4, lam=0.05,
    )
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(s_kern), np.asarray(s_ref), atol=atol)
    assert iters.min() >= 0


@pytest.mark.parametrize("theta,lam", [(0.2, 0.01), (0.6, 0.1), (0.9, 0.5),
                                       (0.99, 1.0)])
def test_sssj_kernel_param_sweep(theta, lam, rng):
    q = _unit_rows(rng, 64, 128, jnp.float32)
    w = _unit_rows(rng, 64, 128, jnp.float32)
    tq = jnp.asarray((rng.random(64) * 5).astype(np.float32)) + 5.0
    tw = jnp.asarray((rng.random(64) * 5).astype(np.float32))
    uq = jnp.arange(100, 164, dtype=jnp.int32)
    uw = jnp.arange(64, dtype=jnp.int32)
    s_k, _ = sssj_join_scores(q, w, tq, tw, uq, uw, theta=theta, lam=lam,
                              block_q=32, block_w=32, chunk_d=32)
    s_r = sssj_join_ref(q, w, tq.reshape(-1, 1), tw.reshape(-1, 1),
                        uq.reshape(-1, 1), uw.reshape(-1, 1),
                        theta=theta, lam=lam)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-5)


def test_sssj_tile_pruning_saves_chunks(rng):
    """Dead tiles (outside the horizon) must not run their k-loop — the
    paper's time filtering at tile granularity."""
    d = 128
    q = _unit_rows(rng, 32, d, jnp.float32)
    w = _unit_rows(rng, 32, d, jnp.float32)
    # window far in the past: every pair outside the horizon
    tq = jnp.full((32,), 1000.0, jnp.float32)
    tw = jnp.zeros((32,), jnp.float32)
    uq = jnp.arange(100, 132, dtype=jnp.int32)
    uw = jnp.arange(32, dtype=jnp.int32)
    s, iters = sssj_join_scores(q, w, tq, tw, uq, uw, theta=0.5, lam=0.1,
                                block_q=32, block_w=32, chunk_d=32)
    assert int(iters.sum()) == 0            # no d-chunk ever executed
    assert float(jnp.abs(s).sum()) == 0.0


@pytest.mark.parametrize("q_n,w_n,d,routed_to_ref", [
    (8, 8, 16, True),      # smaller than one block in every dim → ref
    (100, 8, 128, True),   # window smaller than one block → ref
    (8, 100, 128, True),   # queries smaller than one block → ref
    (64, 64, 16, True),    # feature dim smaller than one chunk → ref
    (64, 64, 64, False),   # at least one block everywhere → kernel
    (64, 96, 128, False),
])
def test_sssj_small_input_ref_routing(q_n, w_n, d, routed_to_ref, rng):
    """Inputs smaller than one block auto-route through the jnp oracle;
    both paths must agree with the reference exactly."""
    from repro.kernels.sssj_join import sssj_join_tiles

    q = _unit_rows(rng, q_n, d, jnp.float32)
    w = _unit_rows(rng, w_n, d, jnp.float32)
    tq = jnp.asarray((rng.random(q_n) * 2).astype(np.float32)) + 1.0
    tw = jnp.asarray((rng.random(w_n) * 2).astype(np.float32))
    uq = jnp.arange(1000, 1000 + q_n, dtype=jnp.int32)
    uw = jnp.arange(w_n, dtype=jnp.int32)
    kw = dict(theta=0.3, lam=0.05, block_q=32, block_w=32, chunk_d=32)
    s, iters, counts = sssj_join_tiles(q, w, tq, tw, uq, uw, **kw)
    s_ref = sssj_join_ref(q, w, tq.reshape(-1, 1), tw.reshape(-1, 1),
                          uq.reshape(-1, 1), uw.reshape(-1, 1),
                          theta=0.3, lam=0.05)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)
    # per-tile emit counts (compaction stage 1) match on either path
    assert int(np.asarray(counts).sum()) == int((np.asarray(s) > 0).sum())
    n_chunks = max(d // 32, 1)
    if routed_to_ref:
        # the ref path reports the full chunk count for every tile
        assert (np.asarray(iters) == n_chunks).all()


def test_suffix_chunk_norms_definition(rng):
    x = jnp.asarray(rng.standard_normal((8, 96)).astype(np.float32))
    out = suffix_chunk_norms(x, 32)
    xs = np.asarray(x)
    for k in range(3):
        want = np.linalg.norm(xs[:, (k + 1) * 32:], axis=1)
        np.testing.assert_allclose(np.asarray(out[:, k]), want, rtol=1e-5)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("B,H,Hkv,S,Dh", [
    (1, 4, 4, 128, 64), (2, 8, 2, 128, 64), (1, 4, 1, 256, 32),
    (2, 6, 3, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, S, Dh, dtype, rng):
    q = jnp.asarray(rng.standard_normal((B, H, S, Dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, Dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, Dh)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, sm_scale=Dh ** -0.5, causal=True)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_flash_attention_unaligned_seq(rng):
    q = jnp.asarray(rng.standard_normal((1, 2, 100, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 100, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 100, 64)), jnp.float32)
    out = flash_attention(q, k, v, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, sm_scale=64 ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------- #
# chunked pure-JAX attention (the model-side memory-bounded path)
# --------------------------------------------------------------------- #
def test_chunked_causal_attention_matches_ref(rng):
    from repro.models.attention import chunked_causal_attention

    B, S, H, KV, hd = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    out = chunked_causal_attention(
        q, k, v, pos, jnp.arange(S, dtype=jnp.int32), hd ** -0.5,
        q_chunk=64, kv_chunk=64,
    )
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), sm_scale=hd ** -0.5, causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
