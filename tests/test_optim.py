"""Optimizer: quantization roundtrip, int8-Adam vs fp32-Adam trajectories,
schedule shape, microbatch-accumulation equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adamw import (
    AdamWConfig, QTensor, apply_adamw, dequantize_q8, init_opt_state,
    opt_state_specs, quantize_q8,
)
from repro.optim.schedule import warmup_cosine


def test_q8_roundtrip_relative_error(rng):
    """Power-law code: wide dynamic range, bounded relative error."""
    for scale in (1e-8, 1e-3, 1.0, 1e4):
        x = jnp.asarray(rng.standard_normal((64, 512)) * scale, jnp.float32)
        t = quantize_q8(x)
        y = dequantize_q8(t)
        err = np.abs(np.asarray(y) - np.asarray(x))
        mag = np.abs(np.asarray(x))
        # elements above 1% of block max reconstruct within ~12%
        blocks = np.asarray(x).reshape(64, 2, 256)
        bmax = np.abs(blocks).max(-1).repeat(256, -1).reshape(64, 512)
        big = mag > 0.01 * bmax
        assert (err[big] <= 0.12 * mag[big] + 1e-12).all()


def test_q8_preserves_zero_and_sign(rng):
    x = jnp.asarray([[0.0, -1.0, 1.0, -1e-5, 1e-5] + [0.0] * 251], jnp.float32)
    t = quantize_q8(x)
    y = np.asarray(dequantize_q8(t))[0]
    assert y[0] == 0.0
    assert y[1] < 0 and y[2] > 0
    assert y[3] <= 0.0 <= y[4]


def _quad_setup(moment_dtype):
    """Minimize ‖x - target‖² with AdamW; returns the loss trajectory."""
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, moment_dtype=moment_dtype)
    target = jnp.asarray(np.random.default_rng(1).standard_normal(512),
                         jnp.float32)
    params = {"w": jnp.zeros((512,), jnp.float32)}
    state = init_opt_state(params, cfg)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    traj = []
    for i in range(60):
        g = jax.grad(loss_fn)(params)
        params, state, _ = apply_adamw(params, g, state, cfg, jnp.float32(0.05))
        traj.append(float(loss_fn(params)))
    return np.array(traj)


def test_int8_adam_tracks_f32():
    t32 = _quad_setup("f32")
    t8 = _quad_setup("int8")
    tb = _quad_setup("bf16")
    assert t32[-1] < t32[0] * 0.05
    assert t8[-1] < t8[0] * 0.10          # int8 converges nearly as fast
    assert tb[-1] < tb[0] * 0.08
    # trajectories stay close in log space
    assert np.abs(np.log(t8[5:] + 1e-9) - np.log(t32[5:] + 1e-9)).mean() < 1.0


def test_opt_state_specs_structure():
    cfg8 = AdamWConfig(moment_dtype="int8")
    params = {"a": jnp.zeros((8, 512)), "b": jnp.zeros(())}
    st = init_opt_state(params, cfg8)
    specs = opt_state_specs({"a": ("fsdp", "ff"), "b": None}, cfg8)
    # QTensor leaves line up with QTensor specs
    assert isinstance(st["m"]["a"], QTensor)
    assert isinstance(specs["m"]["a"], QTensor)
    assert specs["m"]["a"].q == ("fsdp", "ff")
    assert specs["m"]["a"].scale == ("fsdp", None)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), 1.0, 10, 100, 0.1))
           for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6            # peak at end of warmup
    assert lrs[-1] == pytest.approx(0.1, abs=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[2:], lrs[3:]))  # decreasing


def test_grad_clipping_caps_update():
    cfg = AdamWConfig(peak_lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=1, total_steps=10)
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = init_opt_state(params, cfg)
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    _, _, metrics = apply_adamw(params, g, state, cfg, jnp.float32(1.0))
    assert float(metrics["clip"]) < 1e-4
