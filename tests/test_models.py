"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced same-family config, runs one forward and one train step on CPU with
correct shapes and no NaNs; decode matches the full forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, cell_enabled
from repro.models.lm import (
    init_lm, init_lm_caches, lm_decode_step, lm_forward, lm_specs, make_plan,
    param_count,
)
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, rng, B=2, S=32):
    if cfg.input_kind == "embeddings":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.float32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
        }
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return {"tokens": t, "labels": t}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = init_lm(jax.random.key(0), cfg)
    b = _batch(cfg, rng)
    kw = ({"embeds": b["embeds"]} if cfg.input_kind == "embeddings"
          else {"tokens": b["tokens"]})
    logits, aux, _ = lm_forward(params, cfg, **kw)
    B, S = b["labels"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    if cfg.moe is not None:
        assert float(aux) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    tc = TrainConfig(
        optimizer=AdamWConfig(peak_lr=3e-3, warmup_steps=1, total_steps=50),
        remat=True, microbatches=2,
    )
    params, opt = init_train_state(jax.random.key(0), cfg, tc)
    step = jax.jit(build_train_step(cfg, tc))
    b = _batch(cfg, rng, B=4)
    losses = []
    for _ in range(4):
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert not any(np.isnan(losses))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = init_lm(jax.random.key(1), cfg)
    B, S, M = 2, 12, 24
    b = _batch(cfg, rng, B=B, S=S)
    if cfg.input_kind == "embeddings":
        full_kw = {"embeds": b["embeds"]}
        pre_kw = {"embeds": b["embeds"][:, : S - 1]}
        dec_kw = {"tokens": None, "embeds": b["embeds"][:, S - 1 : S]}
    else:
        full_kw = {"tokens": b["tokens"]}
        pre_kw = {"tokens": b["tokens"][:, : S - 1]}
        dec_kw = {"tokens": b["tokens"][:, S - 1 : S]}
    logits_full, _, _ = lm_forward(
        params, cfg, compute_dtype=jnp.float32, moe_dropless=True, **full_kw
    )
    caches = init_lm_caches(cfg, B, M, dtype=jnp.float32)
    _, _, caches = lm_forward(
        params, cfg, caches=caches, cache_len=jnp.int32(0),
        compute_dtype=jnp.float32, moe_dropless=True, **pre_kw
    )
    logits_dec, _ = lm_decode_step(
        params, cfg, caches=caches, cache_len=jnp.int32(S - 1),
        compute_dtype=jnp.float32, **dec_kw
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        atol=2e-3,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_mirror_params(arch):
    cfg = ARCHS[arch].reduced()
    params = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.key(0))
    specs = lm_specs(cfg)
    is_leaf = lambda s: isinstance(s, tuple) and all(
        isinstance(x, (str, type(None))) for x in s
    )
    pt = jax.tree.structure(params)
    st_ = jax.tree.structure(specs, is_leaf=is_leaf)
    assert pt == st_
    # every spec leaf's length matches its array's rank
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.flatten(specs, is_leaf=is_leaf)[0]
    for p, s in zip(flat_p, flat_s):
        assert len(s) == len(p.shape), (s, p.shape)


def test_full_config_dims_exact():
    """The registry must carry the assignment's exact numbers."""
    c = ARCHS["deepseek-v3-671b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (61, 7168, 128, 129_280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8
    c = ARCHS["qwen3-0.6b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (28, 1024, 16, 8, 3072)
    assert c.vocab_size == 151_936 and c.qk_norm
    c = ARCHS["zamba2-2.7b"]
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (54, 2560, 64)
    c = ARCHS["xlstm-350m"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (24, 1024, 4, 0)
    c = ARCHS["deepseek-coder-33b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (62, 7168, 56, 8, 19_200)
    c = ARCHS["chameleon-34b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (48, 8192, 64, 65_536)
    c = ARCHS["musicgen-medium"]
    assert (c.n_layers, c.d_model, c.vocab_size) == (48, 1536, 2048)
    c = ARCHS["olmoe-1b-7b"]
    assert (c.moe.n_experts, c.moe.top_k, c.d_ff) == (64, 8, 1024)
    c = ARCHS["qwen2.5-3b"]
    assert (c.n_layers, c.n_kv_heads, c.d_ff) == (36, 2, 11_008) and c.qkv_bias
    c = ARCHS["codeqwen1.5-7b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (32, 4096, 13_440, 92_416)


def test_cell_grid_counts():
    cells = [(c.name, s.name, ok) for c, s, ok, _ in
             __import__("repro.configs", fromlist=["cells"]).cells()]
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    # long_500k skipped for the 8 non-subquadratic archs
    assert len(skips) == 8
    assert all(s[1] == "long_500k" for s in skips)
    enabled_long = [c for c in cells if c[1] == "long_500k" and c[2]]
    assert {c[0] for c in enabled_long} == {"zamba2-2.7b", "xlstm-350m"}


def test_plan_layer_counts():
    """Scan-group plans must cover exactly n_layers for every arch."""
    for name, cfg in ARCHS.items():
        plan = make_plan(cfg)
        if cfg.xlstm is not None:
            total = sum(g.count * cfg.xlstm.slstm_every for g in plan)
        elif cfg.hybrid is not None:
            total = sum(g.count * cfg.hybrid.shared_every for g in plan)
        else:
            total = sum(g.count for g in plan)
        assert total == cfg.n_layers, name
