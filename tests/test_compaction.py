"""Hierarchical compaction vs the dense oracle, property-based.

The contract under test (DESIGN.md §3):

  * whenever no drop counter fires, the hierarchical path (level-1 tile
    selection → level-2 segmented merge) reproduces the dense-oracle pair
    set **pair-for-pair and score-for-score**, across random shapes,
    thresholds, decay rates, and tile/budget capacities;
  * when a capacity does overflow — ``tile_k`` at level 1 or ``max_pairs``
    at level 2 — every lost pair is counted at its level, the counters sum
    exactly (``survivors + dropped_tile + dropped_budget == true pairs``),
    and the survivors are a prefix-ordered subset of the true pair set;
  * the per-row match mask is exact regardless of any overflow.

The three join implementations ("dense" jnp oracle, "scan" tile-scan, and
the "pallas" kernel in interpret mode) must emit identical candidate
buffers (scores up to kernel float accumulation order).
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:  # optional dev dependency: richer search when present, fixed sweep not
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels.sssj_join import (  # noqa: E402
    compact_pairs,
    merge_candidates,
    sssj_join_candidates,
    sssj_join_ref,
    sssj_join_tiles,
    tile_candidates,
)


def _stream(rng, Q, W, d, clustered):
    """Query/window batch with a controllable amount of near-duplicates."""
    q = rng.standard_normal((Q, d)).astype(np.float32)
    w = rng.standard_normal((W, d)).astype(np.float32)
    if clustered:
        n = min(Q, W) // 2
        w[:n] = q[:n] + 0.02 * rng.standard_normal((n, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    w /= np.linalg.norm(w, axis=1, keepdims=True)
    tq = np.sort(rng.random(Q)).astype(np.float32) + 0.5
    tw = np.sort(rng.random(W)).astype(np.float32)
    uq = np.arange(1000, 1000 + Q, dtype=np.int32)
    uw = np.arange(W, dtype=np.int32)
    uw[::5] = -1                          # empty ring slots
    return map(jnp.asarray, (q, w, tq, tw, uq, uw))


def _dense_truth(scores, uq, uw):
    s = np.asarray(scores)
    qi, wi = np.nonzero(s)
    uq, uw = np.asarray(uq), np.asarray(uw)
    return {
        (int(uq[a]), int(uw[b])): float(s[a, b]) for a, b in zip(qi, wi)
    }


def _buffer_pairs(buf):
    n = int(buf.n_pairs)
    return {
        (int(a), int(b)): float(s)
        for a, b, s in zip(
            np.asarray(buf.uid_a)[:n],
            np.asarray(buf.uid_b)[:n],
            np.asarray(buf.score)[:n],
        )
    }


def _check_hierarchical_vs_oracle(
    seed, q_tiles, w_tiles, ragged, theta, lam, tile_k, max_pairs, clustered
):
    """Exactness when nothing drops; exact per-level accounting when it
    does — across shapes, parameters, and both overflow boundaries."""
    rng = np.random.default_rng(seed)
    B = 32
    Q, W = q_tiles * B, w_tiles * B
    if ragged:                       # exercise padding in both dimensions
        Q, W = Q - 7, W - 5
    q, w, tq, tw, uq, uw = _stream(rng, Q, W, 64, clustered)

    scores, _, _ = sssj_join_tiles(
        q, w, tq, tw, uq, uw,
        theta=theta, lam=lam, block_q=B, block_w=B, chunk_d=32,
    )
    truth = _dense_truth(scores, uq, uw)

    jc = sssj_join_candidates(
        q, w, tq, tw, uq, uw,
        theta=theta, lam=lam, tile_k=tile_k, block_q=B, block_w=B,
        chunk_d=32, impl="scan" if seed % 2 else "dense",
    )
    buf = merge_candidates(jc.cands, max_pairs=max_pairs)
    got = _buffer_pairs(buf)
    n_budget, n_tile = int(buf.n_dropped), int(buf.n_dropped_tile)

    # drop counters always sum exactly — nothing is lost silently
    assert len(got) + n_budget + n_tile == len(truth)
    assert int(np.asarray(jc.cands.emitted).sum()) == len(truth)
    # survivors are true pairs with true scores
    assert got.keys() <= truth.keys()
    for k in got:
        assert abs(got[k] - truth[k]) < 1e-6
    if n_budget == 0 and n_tile == 0:
        # lossless run ⇒ pair-for-pair, score-for-score equality
        assert got.keys() == truth.keys()
        # and agreement with the PR-1 dense global-top-k oracle
        dense_buf = compact_pairs(scores, uq, uw, max_pairs=max_pairs)
        if int(dense_buf.n_dropped) == 0:
            assert got == pytest.approx(_buffer_pairs(dense_buf))
    # the match mask is exact regardless of overflow
    want_mask = (np.asarray(scores) > 0).any(axis=1)
    np.testing.assert_array_equal(np.asarray(jc.row_mask), want_mask)
    # buffer tail is inert
    n = int(buf.n_pairs)
    assert (np.asarray(buf.uid_a)[n:] == -1).all()
    assert (np.asarray(buf.score)[n:] == 0.0).all()


# Fixed sweep: every (overflow × shape-raggedness × impl) regime appears at
# least once, so tier-1 retains full contract coverage without hypothesis.
_SWEEP = [
    # seed, q_tiles, w_tiles, ragged, theta, lam, tile_k, max_pairs, clustered
    (0, 1, 1, False, 0.3, 0.2, 1024, 4096, True),    # lossless
    (1, 2, 3, True, 0.6, 0.02, 1024, 4096, True),    # lossless, ragged
    (2, 1, 2, False, 0.3, 0.2, 4, 4096, True),       # tile_k overflow
    (3, 2, 2, True, 0.3, 0.2, 1024, 8, True),        # max_pairs overflow
    (4, 1, 4, True, 0.3, 0.02, 4, 8, True),          # both levels overflow
    (5, 3, 2, False, 0.9, 1.0, 16, 64, False),       # sparse / mostly dead
    (6, 1, 1, True, 0.6, 0.2, 1, 1, True),           # capacity-1 boundary
]


@pytest.mark.parametrize(
    "seed,q_tiles,w_tiles,ragged,theta,lam,tile_k,max_pairs,clustered", _SWEEP
)
def test_hierarchical_matches_dense_oracle_sweep(
    seed, q_tiles, w_tiles, ragged, theta, lam, tile_k, max_pairs, clustered
):
    _check_hierarchical_vs_oracle(
        seed, q_tiles, w_tiles, ragged, theta, lam, tile_k, max_pairs,
        clustered,
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        q_tiles=st.integers(1, 3),
        w_tiles=st.integers(1, 4),
        ragged=st.booleans(),
        theta=st.sampled_from([0.3, 0.6, 0.9]),
        lam=st.sampled_from([0.02, 0.2, 1.0]),
        tile_k=st.sampled_from([1, 4, 16, 64, 1024]),
        max_pairs=st.sampled_from([1, 8, 64, 4096]),
        clustered=st.booleans(),
    )
    def test_hierarchical_matches_dense_oracle_property(
        seed, q_tiles, w_tiles, ragged, theta, lam, tile_k, max_pairs,
        clustered,
    ):
        _check_hierarchical_vs_oracle(
            seed, q_tiles, w_tiles, ragged, theta, lam, tile_k, max_pairs,
            clustered,
        )


@pytest.mark.parametrize("seed,tile_k,theta", [
    (0, 3, 0.4), (1, 16, 0.8), (2, 1024, 0.4),
])
def test_kernel_candidates_match_jnp_mirrors(seed, tile_k, theta):
    """The Pallas level-1 select (interpret mode) emits buffers identical
    to both jnp mirrors: same indices, uids, counts; scores to kernel
    accumulation tolerance."""
    rng = np.random.default_rng(seed)
    q, w, tq, tw, uq, uw = _stream(rng, 64, 96, 64, clustered=True)
    kw = dict(theta=theta, lam=0.1, tile_k=tile_k, block_q=32, block_w=32,
              chunk_d=32)
    ref = sssj_join_candidates(q, w, tq, tw, uq, uw, impl="dense", **kw)
    for impl in ("scan", "pallas"):
        got = sssj_join_candidates(q, w, tq, tw, uq, uw, impl=impl, **kw)
        for name in ("uid_a", "uid_b", "kept", "emitted"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got.cands, name)),
                np.asarray(getattr(ref.cands, name)),
                err_msg=f"{impl}/{name}",
            )
        np.testing.assert_allclose(
            np.asarray(got.cands.score), np.asarray(ref.cands.score),
            atol=1e-5, err_msg=f"{impl}/score",
        )
        np.testing.assert_array_equal(
            np.asarray(got.row_mask), np.asarray(ref.row_mask)
        )


def test_tile_candidates_order_is_stream_order(rng):
    """Within a tile, survivors must be the *earliest* pairs in row-major
    (stream) order — the overflow contract's "keep the first" clause."""
    scores = np.zeros((4, 8), np.float32)
    hits = [(0, 3), (0, 6), (1, 1), (2, 0), (2, 7), (3, 4)]
    for i, (a, b) in enumerate(hits):
        scores[a, b] = 0.5 + 0.01 * i
    uq = jnp.arange(100, 104, dtype=jnp.int32)
    uw = jnp.arange(8, dtype=jnp.int32)
    cands, row_mask = tile_candidates(
        jnp.asarray(scores), uq, uw, block_q=4, block_w=8, tile_k=4
    )
    assert int(cands.emitted[0]) == 6 and int(cands.kept[0]) == 4
    kept = list(
        zip(np.asarray(cands.uid_a)[0, :4], np.asarray(cands.uid_b)[0, :4])
    )
    assert kept == [(100 + a, b) for a, b in hits[:4]]
    np.testing.assert_array_equal(
        np.asarray(row_mask), np.array([True, True, True, True])
    )
    # merge keeps segment-then-rank order and attributes the tile loss
    buf = merge_candidates(cands, max_pairs=3)
    assert int(buf.n_pairs) == 3
    assert int(buf.n_dropped) == 1 and int(buf.n_dropped_tile) == 2
    got = list(zip(np.asarray(buf.uid_a)[:3], np.asarray(buf.uid_b)[:3]))
    assert got == [(100 + a, b) for a, b in hits[:3]]


def test_scan_impl_exact_on_wrapped_ring(rng):
    """The cursor-anchored live-strip walk must stay exact when the ring
    has wrapped — the newest item sits mid-array and the live range spans
    the wrap boundary.  (The walk is derived from the max uid, so this is
    the case where ``dist`` actually wraps modulo n_strips.)"""
    d, W, Q = 64, 256, 32
    # ring layout: uids [200..391] written cyclically → newest at slot 103
    uw_np = np.roll(np.arange(200, 200 + W, dtype=np.int32), 104)
    tw_np = np.roll(np.linspace(0.0, 25.6, W).astype(np.float32), 104)
    w = rng.standard_normal((W, d)).astype(np.float32)
    q = w[np.roll(np.arange(W), -104)[-Q:]].copy()   # dup the newest items
    q += 0.01 * rng.standard_normal((Q, d)).astype(np.float32)
    w /= np.linalg.norm(w, axis=1, keepdims=True)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    tq = jnp.full((Q,), 25.7)
    uq = jnp.arange(1000, 1000 + Q, dtype=jnp.int32)
    kw = dict(theta=0.6, lam=0.5, tile_k=64, block_q=32, block_w=32,
              chunk_d=32)
    ref = sssj_join_candidates(
        jnp.asarray(q), jnp.asarray(w), tq, jnp.asarray(tw_np), uq,
        jnp.asarray(uw_np), impl="dense", **kw,
    )
    got = sssj_join_candidates(
        jnp.asarray(q), jnp.asarray(w), tq, jnp.asarray(tw_np), uq,
        jnp.asarray(uw_np), impl="scan", **kw,
    )
    assert int(np.asarray(ref.cands.emitted).sum()) > 0   # non-trivial case
    for name in ("uid_a", "uid_b", "kept", "emitted"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got.cands, name)),
            np.asarray(getattr(ref.cands, name)), err_msg=name,
        )
    np.testing.assert_allclose(
        np.asarray(got.cands.score), np.asarray(ref.cands.score), atol=1e-5
    )
    # the walk only visited strips near the cursor: expired strips (far
    # behind slot 103 in ring age) report zero executed chunks
    assert int((np.asarray(got.iters)[0] > 0).sum()) < np.asarray(got.iters).shape[1]


@pytest.mark.parametrize("impl", ["dense", "scan", "pallas"])
def test_stream_lanes_without_per_row_params(impl, rng):
    """Uniform tenants pass stream lanes alone (theta_q/lam_q = None) —
    every impl must accept that and mask cross-stream pairs under the
    static (θ, λ).  Regression: the pallas call once appended None inputs
    for the missing per-row lanes."""
    Q, W, d = 32, 64, 64
    q, w, tq, tw, uq, uw = _stream(rng, Q, W, d, clustered=True)
    sq = jnp.asarray(rng.integers(0, 2, Q).astype(np.int32))
    sw = jnp.asarray(rng.integers(0, 2, W).astype(np.int32))
    kw = dict(theta=0.5, lam=0.1, tile_k=1024, block_q=32, block_w=32,
              chunk_d=32, sq=sq, sw=sw)
    got = sssj_join_candidates(q, w, tq, tw, uq, uw, impl=impl, **kw)
    scores = sssj_join_ref(
        q, w, tq[:, None], tw[:, None], uq[:, None], uw[:, None],
        theta=0.5, lam=0.1, sq=sq[:, None], sw=sw[:, None],
    )
    truth = _dense_truth(scores, uq, uw)
    pairs = _buffer_pairs(merge_candidates(got.cands, max_pairs=4096))
    assert pairs.keys() == truth.keys() and len(truth) > 0
    for k in pairs:
        assert abs(pairs[k] - truth[k]) < 1e-5


@pytest.mark.parametrize("impl", ["dense", "scan", "pallas"])
def test_multi_tenant_lanes_match_across_impls(impl, rng):
    """Stream-equality masking and per-row (θ, λ) must behave identically
    in all three level-1 implementations: candidates equal the dense
    oracle's, cross-stream pairs never appear, and each row obeys its own
    tenant's threshold."""
    Q, W, d = 64, 96, 64
    q, w, tq, tw, uq, uw = _stream(rng, Q, W, d, clustered=True)
    sq = jnp.asarray(rng.integers(0, 3, Q).astype(np.int32))
    sw = jnp.asarray(rng.integers(0, 3, W).astype(np.int32))
    thetas = np.array([0.3, 0.6, 0.9], np.float32)
    lams = np.array([0.2, 0.05, 1.0], np.float32)
    theta_q = jnp.asarray(thetas[np.asarray(sq)])
    lam_q = jnp.asarray(lams[np.asarray(sq)])
    kw = dict(theta=0.5, lam=0.1, tile_k=1024, block_q=32, block_w=32,
              chunk_d=32, sq=sq, sw=sw, theta_q=theta_q, lam_q=lam_q)
    got = sssj_join_candidates(q, w, tq, tw, uq, uw, impl=impl, **kw)
    # brute-force truth with per-row parameters and the stream mask
    sims = np.asarray(q) @ np.asarray(w).T
    dt = np.abs(np.asarray(tq)[:, None] - np.asarray(tw)[None, :])
    dec = sims * np.exp(-np.asarray(lam_q)[:, None] * dt)
    ok = (np.asarray(uw)[None, :] >= 0) & (
        np.asarray(uq)[:, None] > np.asarray(uw)[None, :]
    ) & (np.asarray(sq)[:, None] == np.asarray(sw)[None, :])
    emit = ok & (dec >= np.asarray(theta_q)[:, None])
    truth = {
        (int(np.asarray(uq)[a]), int(np.asarray(uw)[b])): float(dec[a, b])
        for a, b in zip(*np.nonzero(emit))
    }
    buf = merge_candidates(got.cands, max_pairs=4096)
    pairs = _buffer_pairs(buf)
    assert int(buf.n_dropped) == 0 and int(buf.n_dropped_tile) == 0
    assert pairs.keys() == truth.keys()
    for k in pairs:
        assert abs(pairs[k] - truth[k]) < 1e-5
    np.testing.assert_array_equal(np.asarray(got.row_mask), emit.any(axis=1))


@pytest.mark.parametrize("Q", [96, 90])   # aligned and ragged query counts
def test_scan_impl_skips_expired_strips(Q, rng):
    """The scan impl's strip-level time filter must fire for a window
    entirely outside the τ-horizon — including when Q is not a block
    multiple (regression: the bound once read zero-padded timestamps,
    which pinned tq_lo to 0 and kept every strip alive)."""
    d, W = 64, 384
    q = rng.standard_normal((Q, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    w = rng.standard_normal((W, d)).astype(np.float32)
    w /= np.linalg.norm(w, axis=1, keepdims=True)
    tq = jnp.full((Q,), 1000.0)
    tw = jnp.asarray(np.linspace(0, 10, W).astype(np.float32))
    uq = jnp.arange(10_000, 10_000 + Q, dtype=jnp.int32)
    uw = jnp.arange(W, dtype=jnp.int32)
    jc = sssj_join_candidates(
        jnp.asarray(q), jnp.asarray(w), tq, tw, uq, uw,
        theta=0.5, lam=0.1, tile_k=64, block_q=32, block_w=32, chunk_d=32,
        impl="scan",
    )
    assert int((np.asarray(jc.iters) > 0).sum()) == 0   # no strip executed
    assert int(np.asarray(jc.cands.emitted).sum()) == 0
    assert not np.asarray(jc.row_mask).any()


def test_ref_path_matches_on_subblock_inputs(rng):
    """Sub-block inputs auto-route to the dense jnp oracle and still obey
    the full contract."""
    q, w, tq, tw, uq, uw = _stream(np.random.default_rng(5), 9, 13, 16, True)
    scores = sssj_join_ref(
        q, w, tq[:, None], tw[:, None], uq[:, None], uw[:, None],
        theta=0.4, lam=0.1,
    )
    jc = sssj_join_candidates(
        q, w, tq, tw, uq, uw, theta=0.4, lam=0.1, tile_k=16,
        block_q=32, block_w=32, chunk_d=32,
    )
    buf = merge_candidates(jc.cands, max_pairs=64)
    assert _buffer_pairs(buf) == pytest.approx(_dense_truth(scores, uq, uw))
    assert int(buf.n_dropped) == 0 and int(buf.n_dropped_tile) == 0
