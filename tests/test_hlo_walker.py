"""HLO cost walker: known-flops programs, loop trip multiplication,
collective accounting."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.roofline.hlo import analyze_hlo


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops(rng):
    a = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    cost = analyze_hlo(_hlo_of(lambda x, y: x @ y, a, b))
    want = 2 * 128 * 256 * 64
    assert abs(cost.flops - want) / want < 0.01
    # traffic at least the operands + output once
    assert cost.hbm_bytes >= (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_scan_multiplies_flops(rng):
    a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((10, 64, 64)), jnp.float32)

    def f(x, ws):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    cost = analyze_hlo(_hlo_of(f, a, w))
    want = 10 * 2 * 64 * 64 * 64
    assert abs(cost.flops - want) / want < 0.05, cost.flops


def test_nested_scan_multiplies(rng):
    a = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 3, 32, 32)), jnp.float32)

    def f(x, ws):
        def outer(c, wouter):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wouter)
            return c2, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    cost = analyze_hlo(_hlo_of(f, a, w))
    want = 12 * 2 * 32 ** 3
    assert abs(cost.flops - want) / want < 0.05, cost.flops


def test_dynamic_slice_not_charged_full(rng):
    big = jnp.asarray(rng.standard_normal((1000, 256)), jnp.float32)

    def f(x):
        def body(c, i):
            sl = jax.lax.dynamic_slice(x, (i * 10, 0), (10, 256))
            return c + sl.sum(), None
        out, _ = jax.lax.scan(body, 0.0, jnp.arange(100))
        return out

    cost = analyze_hlo(_hlo_of(f, big))
    # reading 100×(10×256×4B)=1MB of windows, NOT 100×full(1MB)=100MB
    assert cost.hbm_bytes < 30e6, cost.hbm_bytes


def test_collectives_counted_with_trips():
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh_for
        from repro.roofline.hlo import analyze_hlo
        mesh = make_mesh_for((4,), ("data",))
        def f(x):
            def body(c, xi):
                return c + jax.lax.psum(xi.sum(), "data"), None
            out, _ = jax.lax.scan(body, 0.0, x)
            return out
        sfn = jax.shard_map(f, mesh=mesh, in_specs=P(None, "data"),
                            out_specs=P())
        x = jnp.ones((8, 4, 16), jnp.float32)
        hlo = jax.jit(sfn).lower(x).compile().as_text()
        c = analyze_hlo(hlo)
        ar = c.collective_ops.get("all-reduce", 0)
        assert ar >= 8, c.collective_ops   # one per scan iteration
        print("collective trips ok", ar)
    """)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr
