"""TPU-native blocked engine vs planted ground truth and the faithful core."""

import numpy as np
import pytest

from repro.core.blocked import BlockedJoinConfig, BlockedStreamJoiner
from repro.data.synth import dense_embedding_stream, planted_duplicates


@pytest.mark.parametrize("theta,lam", [(0.8, 0.05), (0.6, 0.2), (0.95, 0.02)])
def test_blocked_joiner_exact(theta, lam):
    d = 64
    vecs, ts = dense_embedding_stream(320, d, seed=7, rate=2.0)
    truth = planted_duplicates(vecs, ts, theta, lam)
    cfg = BlockedJoinConfig(theta=theta, lam=lam, capacity=512, d=d,
                            block_q=32, block_w=32, chunk_d=32)
    bj = BlockedStreamJoiner(cfg)
    got = set()
    for i in range(0, 320, 64):
        for a, b, s in bj.push(vecs[i:i + 64], ts[i:i + 64]):
            got.add((min(a, b), max(a, b)))
            assert s >= theta
    assert got == truth
    assert bj.overflow == 0


def test_blocked_matches_faithful_core():
    """Dense engine and the paper-faithful sparse core agree on the same
    stream (densified)."""
    from repro.core import brute_force_join, join_stream, make_joiner
    from repro.core.types import StreamItem, sparse_from_dense

    d = 48
    vecs, ts = dense_embedding_stream(200, d, seed=3, rate=1.0, signed=False)
    theta, lam = 0.85, 0.1
    items = [
        StreamItem(i, float(ts[i]), sparse_from_dense(vecs[i]))
        for i in range(200)
    ]
    truth = {p.key() for p in join_stream(make_joiner("STR", "L2", theta, lam),
                                          items)}
    cfg = BlockedJoinConfig(theta=theta, lam=lam, capacity=512, d=d,
                            block_q=32, block_w=32, chunk_d=16)
    bj = BlockedStreamJoiner(cfg)
    got = set()
    for i in range(0, 200, 50):
        for a, b, _ in bj.push(vecs[i:i + 50], ts[i:i + 50]):
            got.add((min(a, b), max(a, b)))
    assert got == truth


def test_emission_overflow_raises():
    """The compat wrapper was lossless pre-engine; a truncated pair list
    must raise, not return silently (repro.engine handles drops itself)."""
    d = 32
    rng = np.random.default_rng(2)
    base = rng.standard_normal(d).astype(np.float32)
    vecs = base + 0.01 * rng.standard_normal((64, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    ts = np.linspace(0.0, 0.01, 64)
    cfg = BlockedJoinConfig(theta=0.9, lam=0.01, capacity=128, d=d,
                            block_q=32, block_w=32, chunk_d=32, max_pairs=8)
    bj = BlockedStreamJoiner(cfg)
    with pytest.raises(RuntimeError, match="max_pairs"):
        for i in range(0, 64, 32):
            bj.push(vecs[i:i + 32], ts[i:i + 32])


def test_ring_overflow_counter():
    """Overwriting still-live items must be counted (window undersized)."""
    d = 32
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((128, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    ts = np.linspace(0.0, 0.1, 128)      # all within any sane horizon
    cfg = BlockedJoinConfig(theta=0.9, lam=0.001, capacity=64, d=d,
                            block_q=32, block_w=32, chunk_d=32)
    bj = BlockedStreamJoiner(cfg)
    for i in range(0, 128, 32):
        bj.push(vecs[i:i + 32], ts[i:i + 32])
    assert bj.overflow > 0


def test_chunk_pruning_telemetry():
    """With a huge θ the ℓ2 early-exit should terminate most tiles early."""
    d = 256
    vecs, ts = dense_embedding_stream(128, d, seed=5, rate=100.0,
                                      dup_frac=0.0)
    cfg = BlockedJoinConfig(theta=0.99, lam=1e-4, capacity=256, d=d,
                            block_q=32, block_w=32, chunk_d=32)
    bj = BlockedStreamJoiner(cfg)
    for i in range(0, 128, 64):
        bj.push(vecs[i:i + 64], ts[i:i + 64])
    assert bj.tiles_total > 0
    max_chunks = d // 32
    # random unit vectors: partial dot + suffix bound falls below 0.99 fast
    assert bj.chunks_executed < bj.tiles_total * max_chunks
