"""Unified observability layer (DESIGN.md §12): registry semantics,
histogram bucket exactness, exposition round-trips, span tracing,
registry↔legacy-stats conformance across engine cells, per-tenant
admission→emission latency attribution, and the pinned metrics schema."""

import json
import math
import os
import re

import jax
import numpy as np
import pytest

from repro.core import Counters
from repro.engine import EngineConfig, StreamEngine, ShardedStreamEngine
from repro.data.synth import dense_embedding_stream
from repro.obs import (
    LATENCY_BOUNDS_S,
    Histogram,
    MetricsRegistry,
    PIPELINE_STAGES,
    SpanTracer,
    histogram_percentile,
    log_buckets,
    merge_disjoint,
    publish_counters,
)
from repro.runtime import MultiTenantRuntime, ShardedFacade, TenantTable
from repro.serving import MultiTenantSSSJService

SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "metrics_schema.json")

K, D = 4, 32


def _cfg(**kw):
    base = dict(theta=0.8, lam=0.05, capacity=256, d=D, micro_batch=16,
                max_pairs=1024, block_q=16, block_w=16, chunk_d=32)
    base.update(kw)
    return EngineConfig(**base)


def _mt_runtime(shards: int = 1, **kw):
    table = TenantTable.uniform(K, 0.8, 0.05)
    engine = None
    if shards > 1:
        engine = ShardedFacade(jax.make_mesh((shards,), ("data",)))
    return MultiTenantRuntime(_cfg(**kw), table, span=2, engine=engine)


def _drive(rt, n_per=24, seed0=50):
    """Submit K interleaved streams, flush, and drain; returns per-tenant
    submitted counts."""
    streams = [
        dense_embedding_stream(n_per, D, seed=seed0 + k, rate=1.0)
        for k in range(K)
    ]
    events = sorted(
        (float(streams[k][1][i]), k, i)
        for k in range(K) for i in range(n_per)
    )
    for _, k, i in events:
        v, t = streams[k]
        rt.submit(k, v[i:i + 1], t[i:i + 1])
    rt.flush(final=True)
    rt.drain_by_tenant()
    return {k: n_per for k in range(K)}


# --------------------------------------------------------------------- #
# histogram bucket-boundary exactness (satellite: exposition primitives)
# --------------------------------------------------------------------- #
def test_log_buckets_exact_boundaries():
    b = log_buckets(1e-5, 64.0, 2.0)
    assert b[0] == 1e-5
    for lo, hi in zip(b, b[1:]):
        assert hi == lo * 2.0          # exact repeated multiplication
    assert b[-2] < 64.0 <= b[-1]
    assert LATENCY_BOUNDS_S == b


def test_log_buckets_rejects_degenerate():
    for lo, hi, g in [(0.0, 1.0, 2.0), (1.0, 1.0, 2.0), (1e-3, 1.0, 1.0)]:
        with pytest.raises(ValueError):
            log_buckets(lo, hi, g)


def test_histogram_le_semantics_at_boundaries():
    h = Histogram("t", bounds=(1.0, 2.0, 4.0))
    # a value exactly at a bound lands in the bucket it upper-bounds
    for v, bucket in [(0.5, 0), (1.0, 0), (1.0000001, 1), (2.0, 1),
                      (4.0, 2), (4.0001, 3)]:
        before = list(h.counts)
        h.observe(v)
        delta = [b - a for a, b in zip(before, h.counts)]
        assert delta == [int(i == bucket) for i in range(4)], v


def test_observe_many_matches_observe():
    vals = np.array([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 9.0, 1.0])
    h1 = Histogram("a", bounds=(1.0, 2.0, 4.0))
    h2 = Histogram("b", bounds=(1.0, 2.0, 4.0))
    for v in vals:
        h1.observe(float(v))
    h2.observe_many(vals)
    assert h1.counts == h2.counts
    assert h1.count == h2.count == vals.size
    assert math.isclose(h1.sum, h2.sum)


def test_histogram_percentile_interpolation():
    h = Histogram("t", bounds=(1.0, 2.0, 4.0))
    assert h.percentile(0.5) == 0.0                    # empty
    h.observe_many(np.full(100, 1.5))                  # all in (1, 2]
    assert 1.0 < h.percentile(0.5) <= 2.0
    assert h.percentile(1.0) == 2.0                    # bucket upper edge
    h2 = Histogram("o", bounds=(1.0,))
    h2.observe(50.0)                                   # overflow bucket
    assert h2.percentile(0.99) == 1.0                  # last finite bound
    with pytest.raises(ValueError):
        h2.percentile(1.5)


def test_percentile_from_snapshot_dict():
    h = Histogram("t")
    h.observe_many(np.array([1e-4] * 90 + [1.0] * 10))
    snap = h.read()
    assert json.loads(json.dumps(snap)) == snap        # JSON round-trip
    assert math.isclose(
        histogram_percentile(snap, 0.5), h.percentile(0.5)
    )


# --------------------------------------------------------------------- #
# registry semantics
# --------------------------------------------------------------------- #
def test_registry_get_or_create_and_kind_guard():
    reg = MetricsRegistry()
    c = reg.counter("x/total")
    c.inc(3)
    assert reg.counter("x/total") is c                 # idempotent getter
    with pytest.raises(TypeError):
        reg.gauge("x/total")                           # kind change = break
    reg.histogram("x/lat")
    with pytest.raises(ValueError):
        reg.histogram("x/lat", bounds=(1.0, 2.0))      # bounds change too


def test_merge_disjoint_raises_on_collision():
    assert merge_disjoint({"a": 1}, {"b": 2}) == {"a": 1, "b": 2}
    with pytest.raises(ValueError, match="pairs_emitted"):
        merge_disjoint({"pairs_emitted": 1}, {"pairs_emitted": 2})


def test_collector_republishes_at_snapshot_time():
    reg = MetricsRegistry()
    state = {"v": 1}
    reg.register_collector(lambda r: r.counter("s/v").set(state["v"]))
    assert reg.snapshot()["s/v"] == 1
    state["v"] = 7                      # externally-owned total moved
    assert reg.snapshot()["s/v"] == 7   # snapshot is coherent, not stale


def test_snapshot_json_and_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("engine/pairs_emitted").inc(5)
    reg.gauge("router/items_queued").set(3)
    reg.info("runtime/eviction").set("quota")
    reg.histogram("latency/admit_to_emit_s").observe_many(
        np.array([1e-4, 2e-3, 0.5])
    )
    snap = json.loads(reg.to_json())
    assert snap["engine/pairs_emitted"] == 5
    assert snap["latency/admit_to_emit_s"]["count"] == 3
    text = reg.prometheus_text()
    assert "# TYPE engine_pairs_emitted counter" in text
    assert "engine_pairs_emitted 5" in text.splitlines()
    assert 'runtime_eviction{value="quota"} 1' in text
    # histogram series are cumulative and end at the +Inf bucket == count
    buckets = re.findall(
        r'latency_admit_to_emit_s_bucket\{le="([^"]+)"\} (\d+)', text
    )
    counts = [int(c) for _, c in buckets]
    assert counts == sorted(counts) and buckets[-1][0] == "+Inf"
    assert counts[-1] == 3
    assert "latency_admit_to_emit_s_count 3" in text


def test_publish_counters_bridges_paper_vocabulary():
    reg = MetricsRegistry()
    c = Counters()
    publish_counters(reg, c)
    c.entries_traversed += 11
    c.full_sims_computed += 4
    c.peak_index_entries = 9
    snap = reg.snapshot()
    assert snap["paper/entries_traversed"] == 11
    assert snap["paper/full_sims_computed"] == 4
    assert snap["paper/peak_index_entries"] == 9
    sch = reg.schema()
    assert sch["paper/entries_traversed"] == "counter"
    assert sch["paper/peak_index_entries"] == "gauge"   # maxima are gauges


# --------------------------------------------------------------------- #
# span tracer
# --------------------------------------------------------------------- #
def test_span_tracer_records_stage_timings():
    reg = MetricsRegistry()
    tr = SpanTracer(reg)
    with tr.span("scan"):
        pass
    tr.record("drain", 0.25)
    snap = reg.snapshot()
    assert snap["span/scan/calls"] == 1
    assert snap["span/scan/time_s"] >= 0.0
    assert snap["span/drain/calls"] == 1
    assert math.isclose(snap["span/drain/time_s"], 0.25)
    assert set(PIPELINE_STAGES) == {
        "admit", "coalesce", "h2d", "scan", "drain", "emit"
    }


def test_jax_trace_hook_degrades_to_noop(tmp_path):
    reg = MetricsRegistry()
    tr = SpanTracer(reg)
    with tr.jax_trace(str(tmp_path / "trace")) as started:
        assert started in (True, False)     # never raises either way
    assert reg.snapshot().get("span/jax_traces", 0) in (0, 1)


# --------------------------------------------------------------------- #
# conformance: registry values == legacy stats() across engine cells
# --------------------------------------------------------------------- #
_ENGINE_KEYS = {
    "n_items": "engine/n_items",
    "chunks_executed": "engine/chunks_executed",
    "tiles_total": "engine/tiles_total",
    "pairs_emitted": "engine/pairs_emitted",
    "pairs_dropped": "engine/pairs_dropped",
    "pairs_dropped_budget": "engine/pairs_dropped_budget",
    "pairs_dropped_tile": "engine/pairs_dropped_tile",
    "window_overflow": "engine/window_overflow",
    "bytes_to_host": "engine/bytes_to_host",
    "bytes_dense_equiv": "engine/bytes_dense_equiv",
}


def _assert_registry_matches_stats(obj, stats):
    snap = obj.metrics()
    for legacy, namespaced in _ENGINE_KEYS.items():
        assert snap[namespaced] == stats[legacy], legacy


def test_single_engine_registry_equals_stats():
    eng = StreamEngine(_cfg())
    vecs, ts = dense_embedding_stream(96, D, seed=1, rate=2.0)
    for i in range(0, 96, 16):
        eng.push(vecs[i:i + 16], ts[i:i + 16])
    ua, _, _ = eng.drain_arrays()
    stats = eng.stats()
    _assert_registry_matches_stats(eng, stats)
    assert stats["n_items"] == 96
    assert stats["pairs_emitted"] == ua.size
    assert eng.metrics()["engine/pairs_emitted"] == ua.size


@pytest.mark.skipif(jax.device_count() < 2, reason="needs ≥ 2 devices")
def test_sharded_engine_registry_equals_stats():
    mesh = jax.make_mesh((2,), ("data",))
    eng = ShardedStreamEngine(_cfg(capacity=128), mesh)
    vecs, ts = dense_embedding_stream(64, D, seed=2, rate=2.0)
    for i in range(0, 64, 16):
        eng.push(vecs[i:i + 16], ts[i:i + 16])
    eng.drain_arrays()
    stats = eng.stats()
    _assert_registry_matches_stats(eng, stats)
    snap = eng.metrics()
    assert snap["engine/n_shards"] == stats["n_shards"] == 2
    for i in range(2):
        for f in ("live_slots", "pairs_emitted", "window_overflow"):
            assert snap[f"engine/shard/{i}/{f}"] == stats["shards"][f][i]
    assert sum(
        snap[f"engine/shard/{i}/pairs_emitted"] for i in range(2)
    ) >= stats["pairs_emitted"] - stats["pairs_dropped"]


@pytest.mark.parametrize("shards", [1, 2])
def test_runtime_registry_equals_stats(shards):
    if jax.device_count() < shards:
        pytest.skip(f"needs ≥ {shards} devices")
    rt = _mt_runtime(shards=shards, capacity=256 if shards == 1 else 128)
    _drive(rt)
    stats = rt.stats()
    snap = rt.metrics()
    _assert_registry_matches_stats(rt, stats)
    assert snap["runtime/n_tenants"] == stats["n_tenants"] == K
    assert snap["router/items_queued"] == stats["items_queued"] == 0
    assert snap["router/items_rejected"] == stats["items_rejected"]
    assert snap["runtime/spans_dispatched"] == stats["spans_dispatched"]
    assert snap["runtime/padded_rows"] == stats["padded_rows"]
    assert snap["runtime/eviction"] == stats["eviction"]
    assert math.isclose(
        stats["queue_delay_mean_s"],
        snap["router/queue_delay_sum_s"]
        / max(snap["router/items_dispatched"], 1),
    )
    for k in range(K):
        ts = rt.tenant_stats(k)
        assert snap[f"tenant/{k}/submitted"] == ts["submitted"]
        assert snap[f"tenant/{k}/pairs_drained"] == ts["pairs_drained"]
        assert snap[f"tenant/{k}/window_overflow"] == ts["window_overflow"]


# --------------------------------------------------------------------- #
# per-tenant admission→emission latency attribution
# --------------------------------------------------------------------- #
def test_latency_histograms_attribute_every_row():
    rt = _mt_runtime()
    per_tenant = _drive(rt)
    snap = rt.metrics()
    total = snap["latency/admit_to_emit_s"]
    assert total["count"] == sum(per_tenant.values())
    assert total["sum"] > 0.0
    for k, n in per_tenant.items():
        h = snap[f"tenant/{k}/latency_s"]
        assert h["count"] == n, f"tenant {k}"
        assert histogram_percentile(h, 0.5) > 0.0
    # pipeline spans saw the dispatch path
    assert snap["span/admit/calls"] == sum(per_tenant.values())
    for stage in ("coalesce", "h2d", "scan", "drain"):
        assert snap[f"span/{stage}/calls"] >= 1, stage
    assert snap["span/emit/calls"] == 1


# --------------------------------------------------------------------- #
# serving facade: one snapshot, every layer
# --------------------------------------------------------------------- #
def test_service_snapshot_spans_all_layers():
    table = TenantTable.uniform(K, 0.8, 0.05)
    svc = MultiTenantSSSJService(
        table, dim=D, capacity=256, micro_batch=16, max_pairs=1024, span=2
    )
    rng = np.random.default_rng(0)
    for k in range(K):
        svc.submit(k, rng.normal(size=(8, D)), np.arange(8, dtype=float))
    svc.flush(final=True)
    snap = svc.snapshot()
    assert svc.registry is svc.runtime.registry
    for probe in ("engine/pairs_emitted", "router/items_admitted",
                  "runtime/spans_dispatched", "latency/admit_to_emit_s",
                  "tenant/0/latency_s", "span/scan/time_s"):
        assert probe in snap, probe
    assert snap["router/items_admitted"] == 8 * K
    assert snap["latency/admit_to_emit_s"]["count"] == 8 * K
    text = svc.prometheus_text()
    assert "engine_pairs_emitted" in text
    assert 'tenant_0_latency_s_bucket{le="+Inf"}' in text
    # legacy dict is a view over the same snapshot
    assert svc.stats()["n_items"] == snap["engine/n_items"]


# --------------------------------------------------------------------- #
# pinned schema: renaming or dropping a metric is a reviewed change
# --------------------------------------------------------------------- #
def normalize_schema(schema):
    """Collapse per-tenant / per-shard indices so the pinned schema is
    cardinality-independent."""
    out = {}
    for name, kind in schema.items():
        name = re.sub(r"tenant/\d+/", "tenant/<k>/", name)
        name = re.sub(r"engine/shard/\d+/", "engine/shard/<s>/", name)
        prev = out.setdefault(name, kind)
        assert prev == kind, f"{name}: {prev} vs {kind}"
    return out


def test_metrics_schema_matches_pinned():
    rt = _mt_runtime()
    _drive(rt)
    got = normalize_schema(rt.registry.schema())
    with open(SCHEMA_PATH) as f:
        want = json.load(f)
    missing = sorted(set(want) - set(got))
    assert not missing, (
        f"metrics dropped or renamed (update tests/metrics_schema.json "
        f"deliberately if intended): {missing}"
    )
    changed = {n: (want[n], got[n]) for n in want if got[n] != want[n]}
    assert not changed, f"metric kinds changed: {changed}"
    extra = sorted(set(got) - set(want))
    assert not extra, (
        f"new metrics not in the pinned schema (add them to "
        f"tests/metrics_schema.json): {extra}"
    )
