"""Multi-device semantics, run in a subprocess with 8 forced host devices
(the main test process must keep seeing 1 device — see conftest)."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_ring_join_exact():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.blocked import BlockedJoinConfig
        from repro.core.distributed import (
            DistributedJoinConfig, init_sharded_window, make_distributed_join_step)
        from repro.data.synth import dense_embedding_stream, planted_duplicates
        theta, lam, d = 0.8, 0.05, 64
        vecs, ts = dense_embedding_stream(256, d, seed=3, rate=2.0)
        truth = planted_duplicates(vecs, ts, theta, lam)
        mesh = jax.make_mesh((8,), ("data",))
        cfg = DistributedJoinConfig(base=BlockedJoinConfig(
            theta=theta, lam=lam, capacity=128, d=d,
            block_q=32, block_w=32, chunk_d=32))
        step = make_distributed_join_step(cfg, mesh)
        state = init_sharded_window(cfg, mesh)
        got, uid0 = set(), 0
        for i in range(0, 256, 64):
            q = jnp.asarray(vecs[i:i+64]); tq = jnp.asarray(ts[i:i+64], jnp.float32)
            uq = jnp.arange(uid0, uid0+64, dtype=jnp.int32)
            w_uids = np.asarray(state.uids)
            state, (s_win, s_self) = step(state, q, tq, uq)
            for a, b in zip(*np.nonzero(np.asarray(s_win))):
                got.add((min(uid0+a, w_uids[b]), max(uid0+a, w_uids[b])))
            for a, b in zip(*np.nonzero(np.asarray(s_self))):
                got.add((min(uid0+a, uid0+b), max(uid0+a, uid0+b)))
            uid0 += 64
        assert got == truth, (len(got), len(truth))
        print("ring join exact:", len(got))
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.distributed.sharding import DEFAULT_RULES, param_shardings, use_rules
        from repro.launch.mesh import make_mesh_for
        from repro.models.lm import lm_specs
        from repro.optim.adamw import AdamWConfig, opt_state_specs
        from repro.train.step import TrainConfig, build_train_step, init_train_state

        cfg = ARCHS["qwen3-0.6b"].reduced(n_layers=2, vocab_size=512)
        tc = TrainConfig(optimizer=AdamWConfig(peak_lr=1e-2, warmup_steps=1,
                                               total_steps=10),
                         remat=True, microbatches=1, z_loss=0.0,
                         compute_dtype="float32")
        rng = np.random.default_rng(0)
        t = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)
        batch = {"tokens": t, "labels": t}

        # single device
        p1, o1 = init_train_state(jax.random.key(0), cfg, tc)
        step1 = jax.jit(build_train_step(cfg, tc))
        p1, o1, m1 = step1(p1, o1, batch)

        # 4×2 mesh (data × model)
        mesh = make_mesh_for((4, 2), ("data", "model"))
        p2, o2 = init_train_state(jax.random.key(0), cfg, tc)
        with use_rules(mesh, DEFAULT_RULES):
            specs = lm_specs(cfg)
            p2 = jax.device_put(p2, param_shardings(specs, p2, mesh, DEFAULT_RULES))
            o2 = jax.device_put(o2, param_shardings(
                opt_state_specs(specs, tc.optimizer), o2, mesh, DEFAULT_RULES))
        base = build_train_step(cfg, tc)
        def stepper(p, o, b):
            with use_rules(mesh, DEFAULT_RULES):
                return base(p, o, b)
        step2 = jax.jit(stepper)
        p2, o2, m2 = step2(p2, o2, batch)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (
            float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-5)
        print("sharded step matches:", float(m1["loss"]), float(m2["loss"]))
    """)


def test_compressed_psum_error_feedback():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import shard_map  # jax 0.4/0.6 compat
        from repro.launch.mesh import make_mesh_for
        from repro.train.grad_sync import compressed_psum, init_ef_state

        mesh = make_mesh_for((8,), ("pod",))
        rng = np.random.default_rng(0)
        # per-pod gradients (8, n) — psum over 'pod' should give the mean
        g_all = rng.standard_normal((8, 4, 512)).astype(np.float32)
        want = g_all.mean(0)

        def body(g, e):
            out, new_e = compressed_psum({"w": g[0]}, {"w": e[0]}, "pod")
            return out["w"][None], new_e["w"][None]

        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P("pod"), P("pod")),
                              out_specs=(P("pod"), P("pod"))))
        e = jnp.zeros_like(jnp.asarray(g_all))
        out, e = f(jnp.asarray(g_all), e)
        got = np.asarray(out)[0]
        # single-step int8 error within quantization tolerance
        assert np.abs(got - want).max() < 0.02 * np.abs(g_all).max()

        # error feedback: averaging the SAME gradient over many steps
        # converges to the exact mean (residual re-injection)
        acc = np.zeros_like(want)
        e = jnp.zeros_like(jnp.asarray(g_all))
        steps = 20
        for _ in range(steps):
            out, e = f(jnp.asarray(g_all), e)
            acc += np.asarray(out)[0]
        acc /= steps
        assert np.abs(acc - want).max() < 2e-3, np.abs(acc - want).max()
        print("EF compression ok")
    """)


def test_checkpoint_reshard_across_meshes():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import tempfile
        from repro.ft.checkpoint import restore_checkpoint, save_checkpoint
        from repro.launch.mesh import make_mesh_for

        x = jnp.asarray(np.arange(64, dtype=np.float32).reshape(8, 8))
        mesh_a = make_mesh_for((8,), ("data",))
        xa = jax.device_put(x, NamedSharding(mesh_a, P("data")))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, {"x": xa})

        mesh_b = make_mesh_for((4, 2), ("data", "model"))
        sh_b = {"x": NamedSharding(mesh_b, P("data", "model"))}
        like = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        out, _, _ = restore_checkpoint(d + "/step_00000001", like, shardings=sh_b)
        np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
        assert out["x"].sharding == sh_b["x"]
        print("reshard ok")
    """)


def test_long_context_decode_shards_kv_seq():
    """SP-decode: a reduced zamba2 decode with kv_seq sharded over model —
    the long_500k regime at test scale."""
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.distributed.sharding import use_rules
        from repro.launch.cells import LONG_RULES
        from repro.launch.mesh import make_mesh_for
        from repro.models.lm import (init_lm, init_lm_caches, lm_decode_step,
                                     lm_forward)
        cfg = ARCHS["zamba2-2.7b"].reduced()
        mesh = make_mesh_for((2, 4), ("data", "model"))
        params = init_lm(jax.random.key(0), cfg)
        B, S, M = 1, 16, 32
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        logits_full, _, _ = lm_forward(params, cfg, tokens=toks,
                                       compute_dtype=jnp.float32)
        caches = init_lm_caches(cfg, B, M, dtype=jnp.float32)
        def pre(p, c, t):
            with use_rules(mesh, LONG_RULES):
                _, _, c2 = lm_forward(p, cfg, tokens=t, caches=c,
                                      cache_len=jnp.int32(0),
                                      compute_dtype=jnp.float32)
                return c2
        caches = jax.jit(pre)(params, caches, toks[:, :S-1])
        def dec(p, c, t):
            with use_rules(mesh, LONG_RULES):
                return lm_decode_step(p, cfg, tokens=t, caches=c,
                                      cache_len=jnp.int32(S-1),
                                      compute_dtype=jnp.float32)[0]
        out = jax.jit(dec)(params, caches, toks[:, S-1:])
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(logits_full[:, -1]), atol=2e-3)
        print("SP decode ok")
    """)
