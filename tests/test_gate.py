"""Device-resident L2/prefix strip gate (DESIGN.md §13): maintenance,
admissibility, and engine integration.

Four contracts:

  * **maintenance invariant** — the summary carried incrementally through
    :func:`refresh_strip_summary` on every policy push equals a full
    :func:`summarize_strips` rebuild of the ring, under all three eviction
    policies, with ring wrap and a ragged (non-``block_w``-multiple)
    capacity;
  * **admissible pruning** — a gated join (scan and Pallas-interpret)
    emits pair-identical candidates to the ungated dense oracle, while
    actually skipping work (``iters`` strictly below the dense count);
  * **impl equivalence** — the Pallas gate variant computes the identical
    gate and stats to the jnp variant;
  * **engine integration** — gate-on vs gate-off engines drain identical
    pair sets; ``l2_gate=True`` on a dense-oracle config is rejected at
    construction; the four ``engine/prune/*`` metrics publish.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.engine.engine import EngineConfig, StreamEngine
from repro.engine.window import init_window, push_with_overflow
from repro.kernels.sssj_join import (
    init_strip_summary,
    refresh_strip_summary,
    sssj_join_candidates,
    strip_gate,
    summarize_strips,
)

D = 32
BW = 16
CHUNK = 16


def _unit(rng, n, d=D):
    v = rng.standard_normal((n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _assert_summary_equal(got, want, ctx=""):
    np.testing.assert_allclose(
        np.asarray(got.vmax), np.asarray(want.vmax), atol=1e-6, err_msg=ctx
    )
    np.testing.assert_allclose(
        np.asarray(got.cnorm), np.asarray(want.cnorm), atol=1e-6, err_msg=ctx
    )
    assert np.array_equal(np.asarray(got.tmin), np.asarray(want.tmin)), ctx
    assert np.array_equal(np.asarray(got.tmax), np.asarray(want.tmax)), ctx
    assert np.array_equal(np.asarray(got.umax), np.asarray(want.umax)), ctx


@pytest.mark.parametrize(
    "eviction,quotas,cap",
    [
        ("oldest", None, 40),   # ragged: 40 = 2.5 strips of 16
        ("oldest", None, 64),
        ("dead", None, 40),
        ("quota", (24, 16), 40),
    ],
)
def test_refresh_matches_full_rebuild(eviction, quotas, cap):
    """Incremental per-write refresh == full summarize, through ring wrap."""
    rng = np.random.default_rng(11)
    n_lanes = len(quotas) if quotas else None
    state = init_window(
        cap, D, n_lanes=n_lanes, eviction=eviction,
        summary_block_w=BW, summary_chunk_d=CHUNK,
    )
    q = jnp.asarray(quotas, jnp.int32) if quotas else None
    uid = 0
    t = 0.0
    for step in range(12):  # 12 × 16 rows ≫ cap → several wraps
        b = 16
        v = _unit(rng, b)
        tq = np.float32(t) + 0.05 * np.arange(b, dtype=np.float32)
        uq = np.arange(uid, uid + b, dtype=np.int32)
        sq = (
            rng.integers(0, n_lanes, b).astype(np.int32)
            if n_lanes else None
        )
        n_valid = b if step % 3 else b - 5  # exercise padded tails too
        uq[n_valid:] = -1
        t += 1.0
        state = push_with_overflow(
            state, jnp.asarray(v), jnp.asarray(tq), jnp.asarray(uq),
            jnp.asarray(n_valid, jnp.int32), jnp.asarray(t, jnp.float32),
            tau=4.0, sq=None if sq is None else jnp.asarray(sq),
            eviction=eviction, quotas=q,
            summary_block_w=BW, summary_chunk_d=CHUNK,
        )
        uid += b
        want = summarize_strips(
            state.vecs, state.ts, state.uids, block_w=BW, chunk_d=CHUNK
        )
        _assert_summary_equal(
            state.summary, want, f"{eviction} cap={cap} step={step}"
        )


def test_refresh_requires_geometry():
    """A summary-carrying state must be pushed with the strip geometry —
    silently skipping the refresh would corrupt the gate."""
    state = init_window(32, D, summary_block_w=BW, summary_chunk_d=CHUNK)
    rng = np.random.default_rng(0)
    v = jnp.asarray(_unit(rng, 4))
    tq = jnp.arange(4, dtype=jnp.float32)
    uq = jnp.arange(4, dtype=jnp.int32)
    with pytest.raises(ValueError, match="summary_block_w"):
        push_with_overflow(
            state, v, tq, uq, jnp.asarray(4, jnp.int32),
            jnp.asarray(1.0, jnp.float32), tau=4.0,
        )


def _window_with_holes(rng, cap, t_hi):
    """A ring in mid-life shape: live rows, expired rows, empty slots.
    Slots carry decreasing timestamps (as ring strips written in stream
    order do), so older strips are genuinely beyond the decay horizon."""
    vecs = _unit(rng, cap)
    ts = (t_hi - 0.15 * np.arange(cap) - rng.random(cap)).astype(np.float32)
    uids = np.arange(cap, dtype=np.int32)
    dead = rng.random(cap) < 0.3
    vecs[dead] = 0.0
    ts[dead] = 3.0e30
    uids[dead] = -1
    return jnp.asarray(vecs), jnp.asarray(ts), jnp.asarray(uids)


@pytest.mark.parametrize("impl", ["scan", "pallas"])
def test_gated_join_matches_dense_oracle(impl):
    """Pair-identical to the dense oracle AND strictly less work."""
    rng = np.random.default_rng(5)
    cap, b = 128, 32
    w, tw, uw = _window_with_holes(rng, cap, t_hi=100.0)
    q = jnp.asarray(_unit(rng, b))
    tq = jnp.asarray(100.0 + 0.1 * np.arange(b, dtype=np.float32))
    uq = jnp.asarray(np.arange(1000, 1000 + b, dtype=np.int32))
    summary = summarize_strips(w, tw, uw, block_w=BW, chunk_d=CHUNK)
    kw = dict(theta=0.4, lam=0.3, tile_k=64, block_q=16, block_w=BW,
              chunk_d=CHUNK, interpret=True)
    dense = sssj_join_candidates(q, w, tq, tw, uq, uw, impl="dense", **kw)
    gated = sssj_join_candidates(
        q, w, tq, tw, uq, uw, impl=impl, summary=summary, **kw
    )
    for name in ("uid_a", "uid_b", "kept", "emitted"):
        assert np.array_equal(
            np.asarray(getattr(dense.cands, name)),
            np.asarray(getattr(gated.cands, name)),
        ), name
    np.testing.assert_allclose(
        np.asarray(dense.cands.score), np.asarray(gated.cands.score),
        atol=1e-5,
    )
    assert np.array_equal(np.asarray(dense.row_mask),
                          np.asarray(gated.row_mask))
    # non-vacuity: λ=0.3 over a 6-time-unit spread must kill some strips
    assert int(jnp.sum(gated.iters)) < int(jnp.sum(dense.iters))
    stats = np.asarray(gated.gate_stats)
    assert stats[0] + stats[1] > 0 and stats[2] >= 1


def test_strip_gate_pallas_matches_jnp():
    rng = np.random.default_rng(9)
    cap, b = 96, 32
    w, tw, uw = _window_with_holes(rng, cap, t_hi=50.0)
    summary = summarize_strips(w, tw, uw, block_w=BW, chunk_d=CHUNK)
    qp = jnp.asarray(_unit(rng, b))
    args = dict(block_q=16, chunk_d=CHUNK,
                tq_lo=jnp.float32(50.0), tq_hi=jnp.float32(52.0),
                th_min=jnp.float32(0.4), lam_min=jnp.float32(0.2))
    g_j, s_j = strip_gate(qp, summary, impl="jnp", **args)
    g_p, s_p = strip_gate(qp, summary, impl="pallas", interpret=True, **args)
    assert np.array_equal(np.asarray(g_j), np.asarray(g_p))
    assert np.array_equal(np.asarray(s_j), np.asarray(s_p))


def test_l2_gate_config_validation():
    base = dict(theta=0.5, lam=0.1, capacity=64, d=D, micro_batch=8,
                block_q=8, block_w=8, chunk_d=16, tile_k=64, max_pairs=256)
    assert EngineConfig(**base, join_impl="scan").gate_enabled
    assert not EngineConfig(**base, join_impl="dense").gate_enabled
    assert not EngineConfig(**base, use_ref=True).gate_enabled
    assert not EngineConfig(**base, join_impl="scan",
                            emit_dense=True).gate_enabled
    assert not EngineConfig(**base, join_impl="scan",
                            l2_gate=False).gate_enabled
    for bad in (dict(join_impl="dense"), dict(emit_dense=True),
                dict(use_ref=True)):
        with pytest.raises(ValueError, match="l2_gate"):
            EngineConfig(**base, l2_gate=True, **bad)


def test_engine_gate_on_off_identical():
    from repro.data.synth import topic_drift_stream

    v, t = topic_drift_stream(768, D, n_topics=4, seg=96, seed=2, rate=4.0)
    base = dict(theta=0.5, lam=0.05, capacity=192, d=D, micro_batch=16,
                block_q=16, block_w=BW, chunk_d=CHUNK, tile_k=256,
                max_pairs=1 << 14, join_impl="scan")

    def drive(cfg):
        eng = StreamEngine(cfg)
        for i in range(0, len(v), 16):
            eng.push(v[i : i + 16], t[i : i + 16])
        ua, ub, sc = eng.drain_arrays()
        o = np.lexsort((ub, ua))
        return ua[o], ub[o], sc[o], eng

    on = drive(EngineConfig(**base))
    off = drive(EngineConfig(**base, l2_gate=False))
    assert len(on[0]) > 0
    assert np.array_equal(on[0], off[0])
    assert np.array_equal(on[1], off[1])
    np.testing.assert_allclose(on[2], off[2], atol=1e-5)
    m = on[3].metrics()
    assert m["engine/prune/tiles_total"] > 0
    skipped = (m["engine/prune/tiles_skipped_time"]
               + m["engine/prune/tiles_skipped_l2"])
    assert 0 < skipped < m["engine/prune/tiles_total"]
    assert m["engine/prune/strips_survived"] > 0
    # gate-off path never runs the gate
    m_off = off[3].metrics()
    assert m_off["engine/prune/tiles_skipped_time"] == 0
    assert m_off["engine/prune/tiles_skipped_l2"] == 0
