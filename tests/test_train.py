"""Train-step semantics: microbatch accumulation equals full-batch grads,
loss decreases, masks respected, MTP plumbed."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.lm import init_lm, lm_forward
from repro.optim.adamw import AdamWConfig
from repro.train.loss import cross_entropy_loss
from repro.train.step import TrainConfig, _loss_fn, build_train_step, init_train_state


def test_cross_entropy_matches_manual(rng):
    B, S, V = 2, 5, 11
    logits = jnp.asarray(rng.standard_normal((B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    loss, metrics = cross_entropy_loss(logits, labels, z_loss=0.0)
    lf = np.asarray(logits)
    p = np.exp(lf - lf.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = -np.log(
        np.take_along_axis(p, np.asarray(labels)[..., None], -1)[..., 0]
    ).mean()
    assert float(loss) == pytest.approx(want, rel=1e-5)


def test_cross_entropy_mask(rng):
    B, S, V = 2, 6, 7
    logits = jnp.asarray(rng.standard_normal((B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.zeros((B, S)).at[:, :3].set(1.0)
    full, _ = cross_entropy_loss(logits[:, :3], labels[:, :3], z_loss=0.0)
    masked, _ = cross_entropy_loss(logits, labels, mask=mask, z_loss=0.0)
    assert float(full) == pytest.approx(float(masked), rel=1e-5)


def test_microbatch_equals_full_batch(rng):
    """Gradient accumulated over k microbatches == single-shot gradient."""
    cfg = ARCHS["qwen3-0.6b"].reduced(n_layers=2)
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": t, "labels": t}
    key = jax.random.key(0)
    params = init_lm(key, cfg)

    tc1 = TrainConfig(remat=False, microbatches=1, z_loss=0.0,
                      compute_dtype="float32")
    g1 = jax.grad(lambda p, b: _loss_fn(p, b, cfg, tc1)[0])(params, batch)
    # per-microbatch mean of grads over equal splits == full grad when the
    # loss is a token mean over equal-size microbatches
    gfn = jax.grad(lambda p, b: _loss_fn(p, b, cfg, tc1)[0])
    halves = [
        {"tokens": t[:2], "labels": t[:2]},
        {"tokens": t[2:], "labels": t[2:]},
    ]
    g2 = jax.tree.map(
        lambda a, b: (a + b) / 2.0, gfn(params, halves[0]), gfn(params, halves[1])
    )
    flat1, flat2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-6)


def test_remat_does_not_change_grads(rng):
    cfg = ARCHS["qwen3-0.6b"].reduced(n_layers=2)
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": t, "labels": t}
    params = init_lm(jax.random.key(0), cfg)
    g_plain = jax.grad(
        lambda p: _loss_fn(p, batch, cfg, TrainConfig(remat=False))[0]
    )(params)
    g_remat = jax.grad(
        lambda p: _loss_fn(p, batch, cfg, TrainConfig(remat=True))[0]
    )(params)
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_remat)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_mtp_loss_present(rng):
    cfg = ARCHS["deepseek-v3-671b"].reduced()
    assert cfg.mtp
    tc = TrainConfig(remat=False, microbatches=1)
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    params = init_lm(jax.random.key(0), cfg)
    loss, metrics = _loss_fn(params, {"tokens": t, "labels": t}, cfg, tc)
    assert "mtp_loss" in metrics
    assert float(metrics["mtp_loss"]) > 0.0
    assert float(loss) > float(metrics["nll"]) * 0.9
