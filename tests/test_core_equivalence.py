"""Every (framework × index) combination must emit the exact pair set of
the brute-force oracle — the paper's correctness contract (no false
negatives from any bound, no false positives from any decay placement)."""

import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    Counters, brute_force_join, join_stream, make_joiner, time_horizon,
)
from repro.core.types import StreamItem, make_sparse, unit_normalize
from repro.data.synth import DATASET_SPECS, StreamSpec, synthetic_stream

COMBOS = [
    ("MB", "INV"), ("MB", "AP"), ("MB", "L2AP"), ("MB", "L2"),
    ("STR", "INV"), ("STR", "L2AP"), ("STR", "L2"),
]


def _pairs(items, fw, idx, theta, lam):
    j = make_joiner(fw, idx, theta, lam)
    return {p.key() for p in join_stream(j, items)}


@pytest.mark.parametrize("fw,idx", COMBOS)
@pytest.mark.parametrize("theta,lam", [(0.7, 0.05), (0.5, 0.2), (0.9, 0.01)])
def test_matches_brute_force(fw, idx, theta, lam):
    spec = StreamSpec("mini", 250, 192, 10.0, "poisson")
    items = synthetic_stream(spec, seed=3)
    truth = {p.key() for p in brute_force_join(items, theta, lam)}
    got = _pairs(items, fw, idx, theta, lam)
    assert got == truth


@pytest.mark.parametrize("name", sorted(DATASET_SPECS))
def test_all_dataset_characters(name):
    """One pass per timestamp character (poisson/sequential/bursty)."""
    base = DATASET_SPECS[name]
    spec = StreamSpec(base.name, 200, 256, min(base.avg_nnz, 24.0),
                      base.timestamps)
    items = synthetic_stream(spec, seed=11)
    theta, lam = 0.6, 0.1
    truth = {p.key() for p in brute_force_join(items, theta, lam)}
    for fw, idx in (("STR", "L2"), ("MB", "L2AP"), ("STR", "INV")):
        assert _pairs(items, fw, idx, theta, lam) == truth, (fw, idx)


@st.composite
def _stream(draw):
    n = draw(st.integers(10, 60))
    dims = draw(st.integers(4, 24))
    items = []
    t = 0.0
    for uid in range(n):
        nnz = draw(st.integers(1, min(dims, 6)))
        idx = draw(
            st.lists(st.integers(0, dims - 1), min_size=nnz, max_size=nnz,
                     unique=True)
        )
        vals = draw(
            st.lists(st.floats(0.05, 1.0), min_size=nnz, max_size=nnz)
        )
        t += draw(st.floats(0.0, 2.0))
        items.append(StreamItem(uid, t, unit_normalize(make_sparse(idx, vals))))
    return items


@given(_stream(), st.sampled_from([0.5, 0.7, 0.9]),
       st.sampled_from([0.02, 0.1, 0.5]))
@settings(max_examples=40, deadline=None)
def test_property_equivalence(items, theta, lam):
    truth = {p.key() for p in brute_force_join(items, theta, lam)}
    for fw, idx in (("STR", "L2"), ("STR", "L2AP"), ("MB", "L2")):
        assert _pairs(items, fw, idx, theta, lam) == truth, (fw, idx)


def test_emitted_scores_correct():
    """Pairs carry the true decayed similarity, not just membership."""
    spec = StreamSpec("mini", 120, 128, 8.0, "bursty")
    items = synthetic_stream(spec, seed=5)
    theta, lam = 0.6, 0.1
    truth = {p.key(): p.decayed for p in brute_force_join(items, theta, lam)}
    j = make_joiner("STR", "L2", theta, lam)
    for p in join_stream(j, items):
        assert p.key() in truth
        assert math.isclose(p.decayed, truth[p.key()], rel_tol=1e-9)


def test_horizon_math():
    assert math.isclose(time_horizon(0.5, 0.1), math.log(2.0) / 0.1)
    assert time_horizon(1.0, 0.5) == 0.0
    assert math.isinf(time_horizon(0.5, 0.0))
    with pytest.raises(ValueError):
        time_horizon(0.0, 0.1)
    with pytest.raises(ValueError):
        time_horizon(0.5, -1.0)


def test_counters_track_work():
    spec = StreamSpec("mini", 150, 128, 10.0, "sequential")
    items = synthetic_stream(spec, seed=9)
    c_inv, c_l2 = Counters(), Counters()
    join_stream(make_joiner("STR", "INV", 0.7, 0.05, counters=c_inv), items)
    join_stream(make_joiner("STR", "L2", 0.7, 0.05, counters=c_l2), items)
    # paper claim: L2 prunes ⇒ traverses no more entries than INV, and
    # indexes no more entries than INV (prefix filtering)
    assert c_l2.entries_traversed <= c_inv.entries_traversed
    assert c_l2.entries_indexed <= c_inv.entries_indexed
    assert c_inv.items_processed == len(items)
