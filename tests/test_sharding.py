"""Logical-axis sharding rules: divisibility fallback, missing-axis drop,
cross-dim conflict resolution."""

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import AxisRules, DEFAULT_RULES, resolve_pspec


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_divisibility_fallback():
    mesh = _mesh1()
    rules = AxisRules(table={"heads": ("model",), "ff": ("model",)})
    # model axis size 1 ⇒ always replicate
    assert resolve_pspec((56, 64), ("heads", "ff"), rules, mesh) == P(None, None)


def test_missing_axis_dropped():
    mesh = _mesh1()  # no 'pod' axis
    rules = AxisRules(table={"batch": ("pod", "data")})
    spec = resolve_pspec((8,), ("batch",), rules, mesh)
    # pod missing → only data considered; size 1 → replicated
    assert spec == P(None)


def test_cross_dim_conflict_first_wins():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((2, 4))

    rules = AxisRules(table={"kv_seq": ("model",), "kv_heads": ("model",)})
    spec = resolve_pspec((32, 8), ("kv_seq", "kv_heads"), rules, FakeMesh())
    assert spec == P("model", None)     # second claim of 'model' dropped


def test_indivisible_replicates():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    rules = DEFAULT_RULES
    # 56 heads over model=16 → replicate; 3072 ff over 16 → shard
    spec = resolve_pspec((4096, 56, 128), ("fsdp", "heads", "head_dim"),
                         rules, FakeMesh())
    assert spec == P("data", None, None)
    spec = resolve_pspec((1024, 3072), ("fsdp", "ff"), rules, FakeMesh())
    assert spec == P("data", "model")
