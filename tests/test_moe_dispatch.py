"""MoE dispatch semantics after the perf M1/M2 rewrites: gather-based
dispatch conservation, per-group capacities, dropless causal consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.configs.base import MoEConfig
from repro.models.lm import init_lm
from repro.models.moe import init_moe, moe
from repro.models.common import Initializer
import dataclasses


def _cfg(**over):
    base = ARCHS["olmoe-1b-7b"].reduced()
    if over:
        return dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, **over)
        )
    return base


def test_dropless_equals_bruteforce(rng):
    """Dropless MoE output == explicit per-token expert mixture."""
    cfg = _cfg()
    params, _ = init_moe(Initializer(jax.random.key(0)), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, _ = moe(params, cfg, x, dropless=True)

    # brute force: every token through its top-k experts
    mc = cfg.moe
    xf = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1)[:, : mc.top_k]
    want = np.zeros_like(xf)
    wg = np.asarray(params["w_gate"])
    wu = np.asarray(params["w_up"])
    wd = np.asarray(params["w_down"])
    for t in range(xf.shape[0]):
        gates = probs[t, order[t]]
        gates = gates / gates.sum()
        for gate, e in zip(gates, order[t]):
            g = xf[t] @ wg[e]
            u = xf[t] @ wu[e]
            silu = g / (1 + np.exp(-g)) * u
            want[t] += gate * (silu @ wd[e])
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1, cfg.d_model), want, atol=2e-4
    )


def test_capacity_drops_bounded_per_group(rng):
    """With capacity dispatch, each expert processes ≤ G · cap_g tokens and
    the output of dropped slots is exactly zero-contribution."""
    cfg = _cfg(capacity_factor=0.5, dispatch_groups=2)
    params, _ = init_moe(Initializer(jax.random.key(1)), cfg)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)
    y, aux = moe(params, cfg, x, dropless=False)
    assert not bool(jnp.isnan(y).any())
    assert float(aux) > 0
    # tighter capacity ⇒ output differs from dropless (drops happened)
    y_full, _ = moe(params, cfg, x, dropless=True)
    assert float(jnp.abs(y - y_full).max()) > 1e-6


def test_group_fallback_when_indivisible(rng):
    """T not divisible by dispatch_groups falls back to one group."""
    cfg = _cfg(dispatch_groups=7)
    params, _ = init_moe(Initializer(jax.random.key(2)), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, _ = moe(params, cfg, x)          # 16 tokens % 7 != 0 → G = 1
    assert y.shape == x.shape


def test_dropless_causal_consistency(rng):
    """A token's dropless-MoE output must not depend on batch composition
    (the property capacity dispatch lacks — serving correctness)."""
    cfg = _cfg()
    params, _ = init_moe(Initializer(jax.random.key(3)), cfg)
    a = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)), jnp.float32)
    ya, _ = moe(params, cfg, a, dropless=True)
    yab, _ = moe(params, cfg, jnp.concatenate([a, b], 0), dropless=True)
    np.testing.assert_allclose(np.asarray(ya[0]), np.asarray(yab[0]),
                               atol=1e-5)
