"""Admissibility invariants: every pruning bound must upper-bound the true
(decayed) similarity it gates — the property that guarantees zero false
negatives (DESIGN.md §8 item 3, §13 for the device strip gate).

Hypothesis-driven when the optional dependency is present, fixed seed
sweeps otherwise (same pattern as ``test_window_policy.py``)."""

import math

import numpy as np
import pytest

try:  # optional dev dependency: richer search when present, fixed sweep not
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.index_l2 import L2FamilyIndex
from repro.core.similarity import decayed_similarity, time_horizon
from repro.core.types import StreamItem, make_sparse, sparse_dot, unit_normalize

DIMS = 16


def _np_vec(rng, dims=DIMS):
    nnz = int(rng.integers(1, 7))
    idx = rng.choice(dims, size=nnz, replace=False)
    vals = rng.random(nnz) * 0.95 + 0.05
    return unit_normalize(make_sparse(idx, vals))


def _np_vecs(seed, n_lo, n_hi, dims=DIMS):
    rng = np.random.default_rng(seed)
    return [_np_vec(rng, dims) for _ in range(int(rng.integers(n_lo, n_hi)))]


if HAVE_HYPOTHESIS:

    @st.composite
    def _vec(draw, dims=DIMS):
        nnz = draw(st.integers(1, 6))
        idx = draw(st.lists(st.integers(0, dims - 1), min_size=nnz,
                            max_size=nnz, unique=True))
        vals = draw(st.lists(st.floats(0.05, 1.0), min_size=nnz,
                             max_size=nnz))
        return unit_normalize(make_sparse(idx, vals))

    @given(st.lists(_vec(), min_size=2, max_size=20),
           st.sampled_from([0.5, 0.7, 0.9]))
    @settings(max_examples=40, deadline=None)
    def test_pscore_bounds_prefix_similarity(vecs, theta):
        """Q[x] (pscore at the indexing boundary) must be ≥ dot(y, x') for
        every later query y — the CV ps1 bound builds on it (Alg. 4 line 3)."""
        index = L2FamilyIndex(theta, 0.0, use_ap=False, use_l2=True)
        items = [StreamItem(i, float(i), v) for i, v in enumerate(vecs)]
        index.construct(items)
        for uid, res in index.R.items():
            prefix = make_sparse(res.indices, res.values)
            for item in items:
                if item.uid == uid:
                    continue
                d = sparse_dot(item.vec, prefix)
                # ‖x'‖ bound: dot(y, x') ≤ ‖x'‖·‖y‖ = ‖x'‖; pscore stores
                # the tighter min(b1, b2) just before the boundary
                assert d <= res.q_pscore + 1e-9 or d < theta, (
                    uid, d, res.q_pscore)

    @given(_vec(), _vec(), st.sampled_from([0.25, 1.0]),
           st.floats(0.0, 5.0))
    @settings(max_examples=60, deadline=None)
    def test_l2_suffix_bound_admissible(x, y, lam, dt):
        """Cauchy–Schwarz on any split point: partial + ‖x_suffix‖·‖y_suffix‖
        must upper-bound the full dot product (the kernel's chunked bound)."""
        xd = np.zeros(DIMS)
        xd[x.indices] = x.values
        yd = np.zeros(DIMS)
        yd[y.indices] = y.values
        full = float(xd @ yd)
        for split in (0, 4, 8, 12, 16):
            partial = float(xd[:split] @ yd[:split])
            bound = partial + float(
                np.linalg.norm(xd[split:]) * np.linalg.norm(yd[split:])
            )
            assert bound >= full - 1e-9
            dec = decayed_similarity(full, dt, lam)
            assert bound * math.exp(-lam * dt) >= dec - 1e-9

    @given(st.floats(0.05, 0.99), st.floats(0.001, 2.0))
    @settings(max_examples=50, deadline=None)
    def test_horizon_is_tight(theta, lam):
        """Just inside the horizon a perfect-similarity pair survives; just
        outside it cannot (the time-filtering theorem, paper §3)."""
        tau = time_horizon(theta, lam)
        inside = decayed_similarity(1.0, tau * 0.999, lam)
        outside = decayed_similarity(1.0, tau * 1.001, lam)
        assert inside >= theta * 0.99
        assert outside < theta + 1e-12


def test_decayed_max_vector_exact():
    """m̂^λ lazy maintenance must equal the exhaustive max (paper §5.3)."""
    from repro.core.index_l2 import _DecayedMax

    rng = np.random.default_rng(0)
    lam = 0.3
    dm = _DecayedMax(lam)
    history = []
    t = 0.0
    for _ in range(200):
        t += float(rng.exponential(0.5))
        idx = rng.choice(8, size=3, replace=False)
        vals = rng.random(3) + 0.01
        v = unit_normalize(make_sparse(idx, vals))
        item = StreamItem(len(history), t, v)
        dm.update(item)
        history.append(item)
        for j in range(8):
            want = 0.0
            for h in history:
                pos = np.nonzero(h.vec.indices == j)[0]
                if pos.size:
                    want = max(
                        want,
                        float(h.vec.values[pos[0]]) * math.exp(-lam * (t - h.t)),
                    )
            got = dm.value_at(j, t)
            assert abs(got - want) < 1e-9, (j, got, want)


# --------------------------------------------------------------------- #
# Device-resident strip gate (DESIGN.md §13) vs the host L2 bound chain
# --------------------------------------------------------------------- #

def _densify(vec, dims=DIMS):
    out = np.zeros(dims, np.float32)
    out[vec.indices] = vec.values
    return out


def _chunked_cs(qd, yd, chunk):
    qs = qd.reshape(-1, chunk)
    ys = yd.reshape(-1, chunk)
    return float(
        np.sum(np.linalg.norm(qs, axis=1) * np.linalg.norm(ys, axis=1))
    )


def _check_strip_bounds_sandwich(vecs):
    """On the same vectors: true dot ≤ per-row chunk-CS bound ≤ host
    whole-vector CS bound, and the device strip bound min(prefix, chunk-ℓ2)
    dominates every live row's dot — the device gate is never tighter than
    the host L2 bound implies (shared admissibility oracle)."""
    import jax.numpy as jnp
    from repro.kernels.sssj_join import summarize_strips

    chunk, bw = 4, 4
    dense = np.stack([_densify(v) for v in vecs])
    n = dense.shape[0]
    ts = jnp.arange(n, dtype=jnp.float32)
    uids = jnp.arange(n, dtype=jnp.int32)
    summary = summarize_strips(
        jnp.asarray(dense), ts, uids, block_w=bw, chunk_d=chunk
    )
    vmax = np.asarray(summary.vmax)
    cnorm = np.asarray(summary.cnorm)
    for qi in range(n):
        qd = dense[qi]
        qcn = np.linalg.norm(qd.reshape(-1, chunk), axis=1)
        for wi in range(n):
            yd = dense[wi]
            true = float(qd @ yd)
            row_cs = _chunked_cs(qd, yd, chunk)
            host_cs = float(np.linalg.norm(qd) * np.linalg.norm(yd))
            assert true <= row_cs + 1e-6 <= host_cs + 2e-6
            s = wi // bw
            prefix_b = float(np.abs(qd) @ vmax[s])
            l2_b = float(qcn @ cnorm[s])
            assert min(prefix_b, l2_b) >= true - 1e-6, (qi, wi)
            # strip chunk-ℓ2 bound can only loosen the row's own chunk-CS
            assert l2_b >= row_cs - 1e-6


def _check_gate_keeps_host_pairs(vecs):
    """Every pair the host L2FamilyIndex (rs2/l2 bound chain) emits must
    survive the device strip gate at the same θ — gating off a host-emitted
    pair would be an inadmissible (false-negative) prune."""
    import jax.numpy as jnp
    from repro.kernels.sssj_join import strip_gate, summarize_strips

    theta, chunk, bw = 0.3, 4, 4
    items = [StreamItem(i, float(i), v) for i, v in enumerate(vecs)]
    index = L2FamilyIndex(theta, 0.0, use_ap=False, use_l2=True)
    pairs = index.construct(items)
    dense = np.stack([_densify(v) for v in vecs])
    n = dense.shape[0]
    ts = jnp.arange(n, dtype=jnp.float32)
    uids = jnp.arange(n, dtype=jnp.int32)
    summary = summarize_strips(
        jnp.asarray(dense), ts, uids, block_w=bw, chunk_d=chunk
    )
    gate, _ = strip_gate(
        jnp.asarray(dense), summary, block_q=1, chunk_d=chunk,
        tq_lo=jnp.float32(0.0), tq_hi=jnp.float32(n),
        th_min=jnp.float32(theta), lam_min=jnp.float32(0.0),
    )
    gate = np.asarray(gate)
    for p in pairs:
        q, w = max(p.uid_a, p.uid_b), min(p.uid_a, p.uid_b)
        assert gate[q, w // bw], (q, w, p.sim)
    return len(pairs)


@pytest.mark.parametrize("seed", range(8))
def test_device_strip_bounds_sandwich(seed):
    _check_strip_bounds_sandwich(_np_vecs(seed, 4, 21))


def test_gate_keeps_every_host_emitted_pair():
    emitted = 0
    for seed in range(10):
        emitted += _check_gate_keeps_host_pairs(_np_vecs(100 + seed, 6, 21))
    assert emitted > 0  # non-vacuous: the host actually emitted pairs


if HAVE_HYPOTHESIS:

    @given(st.lists(_vec(), min_size=4, max_size=20))
    @settings(max_examples=25, deadline=None)
    def test_device_strip_bounds_sandwich_property(vecs):
        _check_strip_bounds_sandwich(vecs)

    @given(st.lists(_vec(), min_size=6, max_size=20))
    @settings(max_examples=20, deadline=None)
    def test_gate_keeps_every_host_emitted_pair_property(vecs):
        _check_gate_keeps_host_pairs(vecs)
