"""Admissibility invariants: every pruning bound must upper-bound the true
(decayed) similarity it gates — the property that guarantees zero false
negatives (DESIGN.md §8 item 3)."""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.index_l2 import L2FamilyIndex
from repro.core.similarity import decayed_similarity, time_horizon
from repro.core.types import StreamItem, make_sparse, sparse_dot, unit_normalize


@st.composite
def _vec(draw, dims=16):
    nnz = draw(st.integers(1, 6))
    idx = draw(st.lists(st.integers(0, dims - 1), min_size=nnz, max_size=nnz,
                        unique=True))
    vals = draw(st.lists(st.floats(0.05, 1.0), min_size=nnz, max_size=nnz))
    return unit_normalize(make_sparse(idx, vals))


@given(st.lists(_vec(), min_size=2, max_size=20),
       st.sampled_from([0.5, 0.7, 0.9]))
@settings(max_examples=40, deadline=None)
def test_pscore_bounds_prefix_similarity(vecs, theta):
    """Q[x] (pscore at the indexing boundary) must be ≥ dot(y, x') for every
    later query y — the CV ps1 bound builds on it (Alg. 4 line 3)."""
    index = L2FamilyIndex(theta, 0.0, use_ap=False, use_l2=True)
    items = [StreamItem(i, float(i), v) for i, v in enumerate(vecs)]
    index.construct(items)
    for uid, res in index.R.items():
        prefix = make_sparse(res.indices, res.values)
        for item in items:
            if item.uid == uid:
                continue
            d = sparse_dot(item.vec, prefix)
            # ‖x'‖ bound: dot(y, x') ≤ ‖x'‖·‖y‖ = ‖x'‖; pscore stores the
            # tighter min(b1, b2) just before the boundary
            assert d <= res.q_pscore + 1e-9 or d < theta, (uid, d, res.q_pscore)


@given(_vec(), _vec(), st.sampled_from([0.25, 1.0]),
       st.floats(0.0, 5.0))
@settings(max_examples=60, deadline=None)
def test_l2_suffix_bound_admissible(x, y, lam, dt):
    """Cauchy–Schwarz on any split point: partial + ‖x_suffix‖·‖y_suffix‖
    must upper-bound the full dot product (the kernel's chunked bound)."""
    dims = 16
    xd = np.zeros(dims)
    xd[x.indices] = x.values
    yd = np.zeros(dims)
    yd[y.indices] = y.values
    full = float(xd @ yd)
    for split in (0, 4, 8, 12, 16):
        partial = float(xd[:split] @ yd[:split])
        bound = partial + float(
            np.linalg.norm(xd[split:]) * np.linalg.norm(yd[split:])
        )
        assert bound >= full - 1e-9
        dec = decayed_similarity(full, dt, lam)
        assert bound * math.exp(-lam * dt) >= dec - 1e-9


@given(st.floats(0.05, 0.99), st.floats(0.001, 2.0))
@settings(max_examples=50, deadline=None)
def test_horizon_is_tight(theta, lam):
    """Just inside the horizon a perfect-similarity pair survives; just
    outside it cannot (the time-filtering theorem, paper §3)."""
    tau = time_horizon(theta, lam)
    inside = decayed_similarity(1.0, tau * 0.999, lam)
    outside = decayed_similarity(1.0, tau * 1.001, lam)
    assert inside >= theta * 0.99
    assert outside < theta + 1e-12


def test_decayed_max_vector_exact():
    """m̂^λ lazy maintenance must equal the exhaustive max (paper §5.3)."""
    from repro.core.index_l2 import _DecayedMax

    rng = np.random.default_rng(0)
    lam = 0.3
    dm = _DecayedMax(lam)
    history = []
    t = 0.0
    for _ in range(200):
        t += float(rng.exponential(0.5))
        idx = rng.choice(8, size=3, replace=False)
        vals = rng.random(3) + 0.01
        v = unit_normalize(make_sparse(idx, vals))
        item = StreamItem(len(history), t, v)
        dm.update(item)
        history.append(item)
        for j in range(8):
            want = 0.0
            for h in history:
                pos = np.nonzero(h.vec.indices == j)[0]
                if pos.size:
                    want = max(
                        want,
                        float(h.vec.values[pos[0]]) * math.exp(-lam * (t - h.t)),
                    )
            got = dm.value_at(j, t)
            assert abs(got - want) < 1e-9, (j, got, want)
