"""Shared benchmark utilities: timing, scaled dataset specs, CSV rows."""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

from repro.core import Counters, join_stream, make_joiner
from repro.data.synth import StreamSpec, synthetic_stream

__all__ = ["BENCH_SPECS", "run_config", "Row", "grid", "fmt_rows"]

# Scaled-down analogues of the paper's Table 1 (sizes cut so the full
# harness completes in minutes on one CPU core; density + timestamp
# character preserved — the quantities compared are *relative*).
BENCH_SPECS: Dict[str, StreamSpec] = {
    "webspam": StreamSpec("webspam", 1200, 4096, 180.0, "poisson", rate=1.0),
    "rcv1": StreamSpec("rcv1", 3000, 2048, 40.0, "sequential", rate=1.0),
    "blogs": StreamSpec("blogs", 4000, 4096, 24.0, "bursty", rate=1.0),
    "tweets": StreamSpec("tweets", 6000, 8192, 8.0, "bursty", rate=1.0),
}


@dataclasses.dataclass
class Row:
    name: str
    value: float
    extra: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.value:.6g},{self.extra}"


def run_config(
    items,
    framework: str,
    index: str,
    theta: float,
    lam: float,
    timeout_s: Optional[float] = None,
) -> Tuple[Optional[float], Counters, int]:
    """Run one (framework × index × θ × λ) config.

    Returns (seconds or None on timeout, counters, n_pairs).  The timeout is
    cooperative (checked between items) — the analogue of the paper's
    3-hour per-config budget.
    """
    c = Counters()
    j = make_joiner(framework, index, theta, lam, counters=c)
    t0 = time.perf_counter()
    pairs = 0
    deadline = t0 + timeout_s if timeout_s else None
    for k, item in enumerate(items):
        pairs += len(j.push(item))
        if deadline and (k & 63) == 0 and time.perf_counter() > deadline:
            return None, c, pairs
    pairs += len(j.finish())
    return time.perf_counter() - t0, c, pairs


def grid(thetas, lams):
    return [(th, lm) for th in thetas for lm in lams]


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(r.csv() for r in rows)
