"""Beyond-paper: throughput of the XLA-compiled blocked join (the jnp ref
path — the kernel itself targets TPU and runs in interpret mode here, so
wall-clock is only meaningful for the compiled dense path), the on-device
pair-compaction stage it feeds (engine emission path), and the roofline
picture of the Pallas kernel from its static work model."""

from __future__ import annotations

import functools
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sssj_join import compact_pairs, sssj_join_tiles
from repro.kernels.sssj_join.ops import sssj_join_scores

from .common import Row


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    Q = W = 512 if fast else 2048
    for d in ((256,) if fast else (256, 1024)):
        q = rng.standard_normal((Q, d)).astype(np.float32)
        w = rng.standard_normal((W, d)).astype(np.float32)
        # plant near-duplicates so the emission path has real pairs to move
        q[: Q // 16] = w[: Q // 16] + 0.05 * rng.standard_normal((Q // 16, d))
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        w /= np.linalg.norm(w, axis=1, keepdims=True)
        tq = np.sort(rng.random(Q) * 100).astype(np.float32) + 0.5
        tw = np.sort(rng.random(W) * 100).astype(np.float32)
        uq = np.arange(W, W + Q, dtype=np.int32)
        uw = np.arange(W, dtype=np.int32)
        args = [jnp.asarray(x) for x in (q, w, tq, tw, uq, uw)]
        kw = dict(theta=0.7, lam=0.05, use_ref=True)
        out, _ = sssj_join_scores(*args, **kw)
        out.block_until_ready()
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            out, _ = sssj_join_scores(*args, **kw)
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        gflops = 2 * Q * W * d / dt / 1e9
        rows.append(Row(f"kernel/ref_dense/Q{Q}xW{W}xd{d}/gflops", gflops,
                        f"{dt*1e3:.1f} ms/join"))

        # join + fused on-device compaction (the engine's emission path):
        # the incremental cost of never moving the dense matrix to the host
        max_pairs = 4096

        @functools.partial(jax.jit, static_argnums=())
        def _join_compact(q, w, tq, tw, uq, uw):
            scores, _, _ = sssj_join_tiles(q, w, tq, tw, uq, uw, **kw)
            return compact_pairs(scores, uq, uw, max_pairs=max_pairs)

        buf = _join_compact(*args)
        jax.block_until_ready(buf)
        t0 = time.perf_counter()
        for _ in range(reps):
            buf = _join_compact(*args)
        jax.block_until_ready(buf)
        dt_c = (time.perf_counter() - t0) / reps
        rows.append(Row(
            f"kernel/compacted/Q{Q}xW{W}xd{d}/overhead_pct",
            100.0 * (dt_c - dt) / dt,
            f"{dt_c*1e3:.1f} ms/join+compact, {int(buf.n_pairs)} pairs",
        ))
        # static work model of the Pallas kernel on v5e for this shape:
        # full-tile FLOPs / peak — the interpret-mode runs validate
        # correctness (tests), the TPU projection belongs to EXPERIMENTS.md
        v5e = 197e12
        t_roof = 2 * Q * W * d / v5e
        rows.append(Row(f"kernel/v5e_roofline/Q{Q}xW{W}xd{d}/us", t_roof * 1e6))
    return rows


def check(rows: List[Row]) -> List[str]:
    return []
