"""Beyond-paper: throughput of the XLA-compiled blocked join (the jnp ref
path — the kernel itself targets TPU and runs in interpret mode here, so
wall-clock is only meaningful for the compiled dense path) + the roofline
picture of the Pallas kernel from its static work model."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sssj_join import sssj_join_scores

from .common import Row


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    Q = W = 512 if fast else 2048
    for d in ((256,) if fast else (256, 1024)):
        q = rng.standard_normal((Q, d)).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        w = rng.standard_normal((W, d)).astype(np.float32)
        w /= np.linalg.norm(w, axis=1, keepdims=True)
        tq = np.sort(rng.random(Q) * 100).astype(np.float32) + 100
        tw = np.sort(rng.random(W) * 100).astype(np.float32)
        uq = np.arange(W, W + Q, dtype=np.int32)
        uw = np.arange(W, dtype=np.int32)
        args = [jnp.asarray(x) for x in (q, w, tq, tw, uq, uw)]
        kw = dict(theta=0.7, lam=0.05, use_ref=True)
        out, _ = sssj_join_scores(*args, **kw)
        out.block_until_ready()
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            out, _ = sssj_join_scores(*args, **kw)
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        gflops = 2 * Q * W * d / dt / 1e9
        rows.append(Row(f"kernel/ref_dense/Q{Q}xW{W}xd{d}/gflops", gflops,
                        f"{dt*1e3:.1f} ms/join"))
        # static work model of the Pallas kernel on v5e for this shape:
        # full-tile FLOPs / peak — the interpret-mode runs validate
        # correctness (tests), the TPU projection belongs to EXPERIMENTS.md
        v5e = 197e12
        t_roof = 2 * Q * W * d / v5e
        rows.append(Row(f"kernel/v5e_roofline/Q{Q}xW{W}xd{d}/us", t_roof * 1e6))
    return rows


def check(rows: List[Row]) -> List[str]:
    return []
