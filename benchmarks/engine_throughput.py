"""Beyond-paper: dense vs compacted emission on the streaming engine.

Two drivers over the identical stream and join configuration (the XLA-
compiled jnp join path, so CPU wall-clock is meaningful — the Pallas kernel
itself targets TPU and only runs interpreted here):

  * **dense** — the pre-engine host loop: one jit call per micro-batch,
    fetch the dense ``(B, capacity)`` + ``(B, B)`` score matrices, extract
    pairs with ``np.nonzero`` on the host;
  * **engine** — :class:`repro.engine.StreamEngine`: one jit'd ``lax.scan``
    per request batch, on-device compaction, async drain of ``(max_pairs,)``
    buffers.

Both drivers are warmed on a prefix of the stream (compilation excluded —
a streaming service runs at steady state) and timed on its continuation.
Reported per driver: items/sec and host←device bytes per request batch.
The claim checked is the tentpole's acceptance criterion: compacted
emission moves O(pairs) bytes, dense moves O(B·capacity), with identical
pair sets.
"""

from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.data.synth import dense_embedding_stream
from repro.engine import EngineConfig, StreamEngine
from repro.engine.window import init_window, push_batch
from repro.kernels.sssj_join import sssj_join_scores

from .common import Row


class _DenseDriver:
    """The pre-engine host loop (kept here as the baseline under test)."""

    def __init__(self, cfg: EngineConfig) -> None:
        self.kw = dict(theta=cfg.theta, lam=cfg.lam, block_q=cfg.block_q,
                       block_w=cfg.block_w, chunk_d=cfg.chunk_d,
                       use_ref=cfg.use_ref)
        self.state = init_window(cfg.capacity, cfg.d)
        self.uid0 = 0
        self.bytes_to_host = 0

    def feed(self, vecs, ts, batch: int) -> set:
        pairs = set()
        for i in range(0, vecs.shape[0], batch):
            q = jnp.asarray(vecs[i:i + batch])
            tq = jnp.asarray(ts[i:i + batch], jnp.float32)
            uq = np.arange(self.uid0, self.uid0 + q.shape[0], dtype=np.int32)
            self.uid0 += q.shape[0]
            w_uids = np.asarray(self.state.uids)
            uqj = jnp.asarray(uq)
            s_win, _ = sssj_join_scores(q, self.state.vecs, tq, self.state.ts,
                                        uqj, self.state.uids, **self.kw)
            s_self, _ = sssj_join_scores(q, q, tq, tq, uqj, uqj, **self.kw)
            s_win = np.asarray(s_win)
            s_self = np.asarray(s_self)
            self.bytes_to_host += s_win.nbytes + s_self.nbytes
            for a, b in zip(*np.nonzero(s_win)):
                pairs.add((int(w_uids[b]), int(uq[a])))
            for a, b in zip(*np.nonzero(s_self)):
                pairs.add((int(uq[b]), int(uq[a])))
            self.state = push_batch(self.state, q, tq, uqj)
        return pairs


class _EngineDriver:
    def __init__(self, cfg: EngineConfig) -> None:
        self.engine = StreamEngine(cfg)

    def feed(self, vecs, ts, batch: int) -> set:
        eng = self.engine
        for i in range(0, vecs.shape[0], batch):
            eng.push(vecs[i:i + batch], ts[i:i + batch])
        ua, ub, _ = eng.drain_arrays()
        return set(zip(ub.tolist(), ua.tolist()))


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = 2048 if fast else 8192
    d, capacity, batch = 256, 1024, 256
    theta, lam = 0.75, 0.05
    # one long stream: a warmup prefix (jit compilation) + a timed suffix
    vecs, ts = dense_embedding_stream(2 * n, d, seed=11, rate=4.0)
    cfg = EngineConfig(theta=theta, lam=lam, capacity=capacity, d=d,
                       micro_batch=128, max_pairs=2048,
                       block_q=128, block_w=128, chunk_d=128, use_ref=True)

    dense = _DenseDriver(cfg)
    engine = _EngineDriver(cfg)

    # warmup pass doubles as the equivalence check
    dense_pairs = dense.feed(vecs[:n], ts[:n], batch)
    engine_pairs = engine.feed(vecs[:n], ts[:n], batch)
    match = dense_pairs == engine_pairs

    d0 = dense.bytes_to_host
    t0 = time.perf_counter()
    dense.feed(vecs[n:], ts[n:], batch)
    t_dense = time.perf_counter() - t0
    dense_bytes = dense.bytes_to_host - d0

    e0 = engine.engine.bytes_to_host
    t0 = time.perf_counter()
    engine.feed(vecs[n:], ts[n:], batch)
    t_engine = time.perf_counter() - t0
    engine_bytes = engine.engine.bytes_to_host - e0

    n_batches = -(-n // batch)
    rows.append(Row("engine/pair_sets_match", float(match),
                    f"{len(engine_pairs)} pairs"))
    rows.append(Row("engine/dense/items_per_s", n / t_dense,
                    f"{t_dense*1e3:.0f} ms"))
    rows.append(Row("engine/compacted/items_per_s", n / t_engine,
                    f"{t_engine*1e3:.0f} ms"))
    rows.append(Row("engine/dense/bytes_per_batch", dense_bytes / n_batches,
                    "O(B·capacity) host←device"))
    rows.append(Row("engine/compacted/bytes_per_batch", engine_bytes / n_batches,
                    "O(max_pairs) host←device"))
    rows.append(Row("engine/bytes_reduction_x", dense_bytes / max(engine_bytes, 1)))
    rows.append(Row("engine/pairs_dropped", float(engine.engine.pairs_dropped)))
    return rows


def check(rows: List[Row]) -> List[str]:
    by = {r.name: r.value for r in rows}
    problems = []
    if by.get("engine/pair_sets_match") != 1.0:
        problems.append("engine pair set differs from dense-extraction oracle")
    if by.get("engine/bytes_reduction_x", 0.0) < 2.0:
        problems.append(
            "compacted emission does not materially cut host←device bytes "
            f"(reduction {by.get('engine/bytes_reduction_x'):.2f}×)"
        )
    if by.get("engine/pairs_dropped", 0.0) != 0.0:
        problems.append("max_pairs overflowed on the benchmark stream")
    return problems
