"""Beyond-paper: hierarchical vs dense compaction on the streaming engine.

Three drivers over identical streams and join parameters (all XLA-compiled
CPU paths, so wall-clock is meaningful — the Pallas kernel itself targets
TPU and only runs interpreted here):

  * **host**   — the pre-engine host loop: one jit call per micro-batch,
    fetch the dense ``(B, capacity)`` + ``(B, B)`` score matrices, extract
    pairs with ``np.nonzero`` on the host;
  * **dense**  — the PR-1 engine (``emit_dense=True``): scan-pipelined, but
    every micro-batch materializes the dense score matrix in HBM and
    compacts it with one global ``lax.top_k`` over ``B·(capacity+B)``
    elements;
  * **hier**   — the hierarchical engine (default): level-1 per-tile
    candidate selection fused into the join (dead strips are skipped by the
    tile-level time filter), level-2 segmented merge.  No ``O(B·capacity)``
    array is ever allocated or sorted.

Claims checked (ISSUE 2 acceptance):

  * identical pair sets across all three drivers;
  * hier ≥ 2× dense items/sec at ``capacity ≥ 16384``;
  * hier runs at a capacity whose dense per-micro-batch intermediate
    (reported as a peak-memory estimate) would dwarf the old path;
  * compacted emission still moves O(pairs) host←device bytes.

A compaction-stage timing breakdown (global top-k vs tile-select + merge on
the same workload) and per-path peak-intermediate estimates are reported,
and everything is emitted machine-readably to ``BENCH_engine.json``.

Standalone usage (CI smoke runs this):

    PYTHONPATH=src python -m benchmarks.engine_throughput --smoke
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synth import StreamSpec, dense_embedding_stream, synthetic_stream
from repro.engine import EngineConfig, StreamEngine
from repro.engine.window import init_window, push_with_overflow
from repro.kernels.sssj_join import (
    compact_pairs,
    merge_candidates,
    sssj_join_scores,
    tile_candidates,
)
from repro.obs import publish_counters

from .common import Row, run_config

JSON_PATH = "BENCH_engine.json"


class _HostDriver:
    """The pre-engine host loop (kept here as the historical baseline)."""

    def __init__(self, cfg: EngineConfig) -> None:
        self.kw = dict(theta=cfg.theta, lam=cfg.lam, block_q=cfg.block_q,
                       block_w=cfg.block_w, chunk_d=cfg.chunk_d,
                       use_ref=cfg.use_ref)
        self.state = init_window(cfg.capacity, cfg.d)
        self.tau = cfg.tau
        self.uid0 = 0
        self.bytes_to_host = 0

    def feed(self, vecs, ts, batch: int) -> set:
        pairs = set()
        for i in range(0, vecs.shape[0], batch):
            q = jnp.asarray(vecs[i:i + batch])
            tq = jnp.asarray(ts[i:i + batch], jnp.float32)
            uq = np.arange(self.uid0, self.uid0 + q.shape[0], dtype=np.int32)
            self.uid0 += q.shape[0]
            w_uids = np.asarray(self.state.uids)
            uqj = jnp.asarray(uq)
            s_win, _ = sssj_join_scores(q, self.state.vecs, tq, self.state.ts,
                                        uqj, self.state.uids, **self.kw)
            s_self, _ = sssj_join_scores(q, q, tq, tq, uqj, uqj, **self.kw)
            s_win = np.asarray(s_win)
            s_self = np.asarray(s_self)
            self.bytes_to_host += s_win.nbytes + s_self.nbytes
            for a, b in zip(*np.nonzero(s_win)):
                pairs.add((int(w_uids[b]), int(uq[a])))
            for a, b in zip(*np.nonzero(s_self)):
                pairs.add((int(uq[b]), int(uq[a])))
            self.state = push_with_overflow(
                self.state, q, tq, uqj, jnp.int32(q.shape[0]), tq.max(),
                self.tau,
            )
        return pairs


class _EngineDriver:
    def __init__(self, cfg: EngineConfig) -> None:
        self.engine = StreamEngine(cfg)

    def feed(self, vecs, ts, batch: int) -> set:
        eng = self.engine
        for i in range(0, vecs.shape[0], batch):
            eng.push(vecs[i:i + batch], ts[i:i + batch])
        ua, ub, _ = eng.drain_arrays()
        return set(zip(ub.tolist(), ua.tolist()))


def _timed_feed(driver, vecs, ts, batch):
    t0 = time.perf_counter()
    driver.feed(vecs, ts, batch)
    return time.perf_counter() - t0


def _compaction_stage_ms(scores, uq, uw_all, mb, cap, tile_k, max_pairs, reps=5):
    """Identical workload through both compaction schemes, join excluded."""
    dense_c = jax.jit(lambda s: compact_pairs(s, uq, uw_all, max_pairs=max_pairs))
    hier_sel = jax.jit(
        lambda s: tile_candidates(s, uq, uw_all, block_q=mb, block_w=mb,
                                  tile_k=tile_k)[0]
    )
    hier_mrg = jax.jit(lambda c: merge_candidates(c, max_pairs=max_pairs))

    def clock(f, *a):
        jax.block_until_ready(f(*a))          # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            r = f(*a)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / reps * 1e3

    t_dense = clock(dense_c, scores)
    cands = hier_sel(scores)
    return t_dense, clock(hier_sel, scores), clock(hier_mrg, cands)


def run(fast: bool = True, smoke: bool = False) -> List[Row]:
    rows: List[Row] = []
    if smoke:
        n, d, batch, mb = 512, 32, 128, 64
        cap_small, cap_big, cap_huge = 512, 1024, 4096
    elif fast:
        n, d, batch, mb = 2048, 64, 256, 128
        cap_small, cap_big, cap_huge = 1024, 16384, 1 << 18
    else:
        n, d, batch, mb = 8192, 64, 256, 128
        cap_small, cap_big, cap_huge = 1024, 65536, 1 << 20
    theta, lam = 0.75, 0.05
    max_pairs, tile_k = 2048, 256
    rows.append(Row("engine/smoke_mode", float(smoke)))
    rows.append(Row("engine/capacity_big", float(cap_big)))

    def cfg(capacity, **kw):
        base = dict(theta=theta, lam=lam, capacity=capacity, d=d,
                    micro_batch=mb, max_pairs=max_pairs, tile_k=tile_k,
                    block_q=mb, block_w=mb, chunk_d=min(d, 128))
        base.update(kw)
        return EngineConfig(**base)

    # one long stream: a warmup prefix (jit compilation + window fill) and a
    # timed continuation — a streaming service runs at steady state
    vecs, ts = dense_embedding_stream(2 * n, d, seed=11, rate=4.0)

    # ---- equivalence at a small capacity: all three drivers, one truth ----
    host = _HostDriver(cfg(cap_small, use_ref=True))
    dense = _EngineDriver(cfg(cap_small, emit_dense=True, use_ref=True))
    hier = _EngineDriver(cfg(cap_small))
    host_pairs = host.feed(vecs[:n], ts[:n], batch)
    dense_pairs = dense.feed(vecs[:n], ts[:n], batch)
    hier_pairs = hier.feed(vecs[:n], ts[:n], batch)
    match = host_pairs == dense_pairs == hier_pairs
    rows.append(Row("engine/pair_sets_match", float(match),
                    f"{len(hier_pairs)} pairs, 3 drivers"))

    h0 = host.bytes_to_host
    t_host = _timed_feed(host, vecs[n:], ts[n:], batch)
    rows.append(Row("engine/host/items_per_s", n / t_host,
                    f"cap={cap_small}, {t_host*1e3:.0f} ms"))
    rows.append(Row("engine/host/bytes_per_batch",
                    (host.bytes_to_host - h0) / (-(-n // batch)),
                    "O(B·capacity) host←device"))
    e0 = hier.engine.bytes_to_host
    t_hier_small = _timed_feed(hier, vecs[n:], ts[n:], batch)
    rows.append(Row("engine/hier/items_per_s", n / t_hier_small,
                    f"cap={cap_small}, {t_hier_small*1e3:.0f} ms"))
    rows.append(Row("engine/hier/bytes_per_batch",
                    (hier.engine.bytes_to_host - e0) / (-(-n // batch)),
                    "O(max_pairs) host←device"))
    rows.append(Row("engine/bytes_reduction_x",
                    (host.bytes_to_host - h0)
                    / max(hier.engine.bytes_to_host - e0, 1)))
    rows.append(Row("engine/pairs_dropped",
                    float(hier.engine.pairs_dropped)))

    # ---- paper-counters bridge (DESIGN.md §12) ----------------------------
    # the paper's host-side Fig. 2/6 counters (entries traversed,
    # candidates generated, full similarities) and the device engine's
    # telemetry, published into ONE registry and read from one snapshot
    n_ref = 200 if smoke else 600
    spec = StreamSpec("bridge", n_ref, 1024, 16.0, "poisson", rate=1.0)
    _, c_ref, ref_pairs = run_config(
        synthetic_stream(spec, seed=9), "STR", "L2", theta, 0.05
    )
    publish_counters(hier.engine.registry, c_ref)
    snap = hier.engine.metrics()
    rows.append(Row("paper/entries_traversed",
                    float(snap["paper/entries_traversed"]),
                    "STR × L2 reference joiner (Fig. 2/6 vocabulary)"))
    rows.append(Row("paper/candidates_generated",
                    float(snap["paper/candidates_generated"])))
    rows.append(Row("paper/full_sims_computed",
                    float(snap["paper/full_sims_computed"])))
    rows.append(Row("paper/pairs_emitted", float(snap["paper/pairs_emitted"]),
                    f"{ref_pairs} pairs over {n_ref} items"))
    rows.append(Row("obs/unified_snapshot", float(
        snap["paper/items_processed"] == n_ref
        and snap["engine/n_items"] == 2 * n
    ), "paper/… and engine/… coherent in one registry snapshot"))

    # ---- the tentpole claim: hier ≥ 2× dense at a large capacity ----------
    dense_big = _EngineDriver(cfg(cap_big, emit_dense=True, use_ref=True))
    hier_big = _EngineDriver(cfg(cap_big))
    pd = dense_big.feed(vecs[:n], ts[:n], batch)      # warmup + fill
    ph = hier_big.feed(vecs[:n], ts[:n], batch)
    match_big = pd == ph
    t_dense_big = _timed_feed(dense_big, vecs[n:], ts[n:], batch)
    t_hier_big = _timed_feed(hier_big, vecs[n:], ts[n:], batch)
    rows.append(Row("engine/dense_bigcap/items_per_s", n / t_dense_big,
                    f"cap={cap_big}, {t_dense_big*1e3:.0f} ms"))
    rows.append(Row("engine/hier_bigcap/items_per_s", n / t_hier_big,
                    f"cap={cap_big}, {t_hier_big*1e3:.0f} ms"))
    rows.append(Row("engine/hier_speedup_x", t_dense_big / t_hier_big,
                    f"vs PR-1 dense compaction at cap={cap_big}"))
    rows.append(Row("engine/bigcap_pair_sets_match", float(match_big)))

    # ---- strip-gate skip fraction (DESIGN.md §13) -------------------------
    # the hier drivers run gate-auto-on; a larger window holds more expired
    # history, so the admissible skip fraction must grow with capacity
    for label, drv, cap in (("smallcap", hier, cap_small),
                            ("bigcap", hier_big, cap_big)):
        m = drv.engine.metrics()
        total = max(m["engine/prune/tiles_total"], 1)
        skipped = (m["engine/prune/tiles_skipped_time"]
                   + m["engine/prune/tiles_skipped_l2"])
        rows.append(Row(f"engine/prune/{label}_skip_frac", skipped / total,
                        f"cap={cap}, survived="
                        f"{m['engine/prune/strips_survived']}"))

    # ---- compaction-stage breakdown on the identical dense workload -------
    rng = np.random.default_rng(3)
    sc = np.where(rng.random((mb, cap_big + mb)) < 2e-4,
                  rng.uniform(theta, 1.0, (mb, cap_big + mb)), 0.0)
    scores = jnp.asarray(sc, jnp.float32)
    uq = jnp.arange(cap_big, cap_big + mb, dtype=jnp.int32)
    uw_all = jnp.arange(cap_big + mb, dtype=jnp.int32)
    t_topk, t_sel, t_mrg = _compaction_stage_ms(
        scores, uq, uw_all, mb, cap_big, tile_k, max_pairs
    )
    rows.append(Row("compact_stage/dense_topk_ms", t_topk,
                    f"lax.top_k over {mb*(cap_big+mb)/1e6:.1f}M"))
    rows.append(Row("compact_stage/tile_select_ms", t_sel,
                    "level-1 (from dense input; fused into join in engine)"))
    rows.append(Row("compact_stage/merge_ms", t_mrg,
                    f"level-2 over {(cap_big+mb)//mb + 1} segments"))

    # ---- peak per-micro-batch intermediate estimates ----------------------
    n_tiles = (cap_big + mb) // mb + 1
    dense_bytes = 4 * mb * (cap_big + mb)
    hier_bytes = n_tiles * (tile_k * 8 + 12) + 4 * mb
    rows.append(Row("peak_mem/dense_intermediate_bytes", float(dense_bytes),
                    f"(B, capacity+B) f32 at cap={cap_big}"))
    rows.append(Row("peak_mem/hier_intermediate_bytes", float(hier_bytes),
                    f"{n_tiles} tiles × tile_k={tile_k} candidates"))

    # ---- capacity the dense intermediate could not reasonably hold --------
    nh = max(n // 2, 2 * batch)
    hv, hts = dense_embedding_stream(2 * nh, 32, seed=7, rate=4.0)
    huge = _EngineDriver(EngineConfig(
        theta=theta, lam=lam, capacity=cap_huge, d=32, micro_batch=mb,
        max_pairs=max_pairs, tile_k=tile_k, block_q=mb,
        block_w=min(2048, cap_huge), chunk_d=32,
    ))
    huge.feed(hv[:nh], hts[:nh], batch)
    t_huge = _timed_feed(huge, hv[nh:], hts[nh:], batch)
    rows.append(Row("engine/hugecap/items_per_s", nh / t_huge,
                    f"cap={cap_huge}, dense equiv "
                    f"{4*mb*(cap_huge+mb)/1e6:.0f} MB/micro-batch"))
    rows.append(Row("engine/hugecap/pairs_dropped",
                    float(huge.engine.pairs_dropped)))
    return rows


def check(rows: List[Row]) -> List[str]:
    by = {r.name: r.value for r in rows}
    problems = []
    if by.get("engine/pair_sets_match") != 1.0:
        problems.append("hierarchical pair set differs from dense oracles")
    if by.get("engine/bigcap_pair_sets_match") != 1.0:
        problems.append("pair sets diverge at large capacity")
    if by.get("engine/bytes_reduction_x", 0.0) < 2.0:
        problems.append(
            "compacted emission does not materially cut host←device bytes "
            f"(reduction {by.get('engine/bytes_reduction_x'):.2f}×)"
        )
    if by.get("engine/pairs_dropped", 0.0) != 0.0:
        problems.append("emission overflowed on the benchmark stream")
    if by.get("obs/unified_snapshot") != 1.0:
        problems.append(
            "paper counters and engine telemetry incoherent in the unified "
            "registry snapshot"
        )
    if by.get("paper/entries_traversed", 0.0) <= 0.0 or \
            by.get("paper/full_sims_computed", 0.0) <= 0.0:
        problems.append("paper-counters bridge published empty counters")
    if by.get("engine/hugecap/pairs_dropped", 0.0) != 0.0:
        problems.append("emission overflowed at the huge capacity")
    small = by.get("engine/prune/smallcap_skip_frac", 0.0)
    big = by.get("engine/prune/bigcap_skip_frac", 0.0)
    if not 0.0 < big < 1.0:
        problems.append(f"strip gate vacuous at big capacity ({big})")
    if big < small - 0.02:
        problems.append(
            f"skip fraction not growing with capacity: {small:.3f} → {big:.3f}"
        )
    if not by.get("engine/smoke_mode") and by.get("engine/hier_speedup_x", 0.0) < 2.0:
        problems.append(
            "hierarchical compaction under 2× vs dense at capacity "
            f"{by.get('engine/capacity_big'):.0f} "
            f"({by.get('engine/hier_speedup_x'):.2f}×)"
        )
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI): exercises every path, relaxes "
                         "the wall-clock claim")
    ap.add_argument("--full", action="store_true", help="paper-scale shapes")
    ap.add_argument("--json", default=JSON_PATH,
                    help=f"machine-readable output path (default {JSON_PATH})")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(fast=not args.full, smoke=args.smoke)
    print("name,value,extra")
    for r in rows:
        print(r.csv())
    problems = check(rows)
    payload = {
        "benchmark": "engine_throughput",
        "mode": "smoke" if args.smoke else ("fast" if not args.full else "full"),
        "elapsed_s": round(time.time() - t0, 3),
        "rows": [dict(name=r.name, value=r.value, extra=r.extra) for r in rows],
        "problems": problems,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json} ({len(rows)} rows) in {payload['elapsed_s']}s")
    for p in problems:
        print(f"# CLAIM-FAIL {p}")
    sys.exit(1 if problems else 0)


if __name__ == "__main__":
    main()
