"""Paper Table 2: fraction of (θ, λ) configs that finish within the budget.

The paper ran 24 configs per (dataset × framework × index) with a 3-hour
budget; MB fails by timeout on the large bursty datasets (too-frequent
index rebuilds at small τ), STR completes everywhere.  Scaled here: a 6-
config grid with a per-config budget proportional to the dataset size.
"""

from __future__ import annotations

from typing import List

from repro.data.synth import synthetic_stream

from .common import BENCH_SPECS, Row, grid, run_config

THETAS = (0.6, 0.9)
LAMS = (0.01, 0.1, 1.0)


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    budget = 3.0 if fast else 20.0
    datasets = ("rcv1", "tweets") if fast else tuple(BENCH_SPECS)
    for ds in datasets:
        items = synthetic_stream(BENCH_SPECS[ds], seed=1)
        for fw in ("MB", "STR"):
            for idx in ("INV", "L2AP", "L2"):
                done = 0
                total = 0
                for th, lm in grid(THETAS, LAMS):
                    total += 1
                    secs, _, _ = run_config(items, fw, idx, th, lm,
                                            timeout_s=budget)
                    done += secs is not None
                rows.append(
                    Row(f"table2/{ds}/{fw}-{idx}/completion", done / total,
                        f"budget={budget}s configs={total}")
                )
    return rows


def check(rows: List[Row]) -> List[str]:
    """Paper claim: STR completes at least as often as MB everywhere."""
    problems = []
    by = {r.name: r.value for r in rows}
    for name, v in by.items():
        if "/STR-" in name:
            mb = name.replace("/STR-", "/MB-")
            if mb in by and v < by[mb] - 1e-9:
                problems.append(f"{name}: STR {v} < MB {by[mb]}")
    return problems
