"""Paper Fig. 2: posting entries traversed, STR / MB, as a function of τ.

Claim: the ratio is < 1 (STR does less index work) and decreases toward
~0.65 as the horizon grows (MB inherently tests up-to-2τ-apart pairs)."""

from __future__ import annotations

import math
from typing import List

from repro.data.synth import synthetic_stream

from .common import BENCH_SPECS, Row, run_config

THETA = 0.7


def run(fast: bool = True) -> List[Row]:
    ds = "rcv1"
    items = synthetic_stream(BENCH_SPECS[ds], seed=2)
    rows: List[Row] = []
    lams = (1.0, 0.3, 0.1, 0.03, 0.01) if not fast else (1.0, 0.1, 0.01)
    for lam in lams:
        tau = math.log(1 / THETA) / lam
        _, c_mb, _ = run_config(items, "MB", "L2", THETA, lam)
        _, c_str, _ = run_config(items, "STR", "L2", THETA, lam)
        if c_mb.entries_traversed == 0:
            # degenerate horizon (window holds <1 item): both do no index
            # work — the paper's "ratio tends to one for small τ" endpoint
            ratio = 1.0 if c_str.entries_traversed == 0 else float("inf")
        else:
            ratio = c_str.entries_traversed / c_mb.entries_traversed
        rows.append(Row(f"fig2/{ds}/tau={tau:.2f}/str_over_mb", ratio,
                        f"str={c_str.entries_traversed} mb={c_mb.entries_traversed}"))
    return rows


def check(rows: List[Row]) -> List[str]:
    problems = []
    vals = [(float(r.name.split("tau=")[1].split("/")[0]), r.value)
            for r in rows]
    vals.sort()
    for tau, v in vals:
        if not v <= 1.05:
            problems.append(f"fig2: ratio {v:.3f} > 1 at tau={tau}")
    # largest horizon should show a clear advantage
    if vals and vals[-1][1] > 0.9:
        problems.append(f"fig2: no STR advantage at large tau ({vals[-1][1]:.3f})")
    return problems
