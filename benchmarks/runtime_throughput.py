"""Beyond-paper: multi-tenant coalescing vs sequential per-tenant pushes.

The serving shape the ROADMAP targets — many small independent streams —
is hostile to the micro-batched engine: a tenant submitting 4 items at a
time pads every micro-batch 32× and pays a full window scan per push.
The runtime's router coalesces sub-batch arrivals across tenants into
full micro-batches (DESIGN.md §9), so per-arrival device cost tracks
output, not tenant count.

Two drivers over the identical interleaved traffic (T tenants, each
submitting ``per_round`` items per round, globally time-ordered), both on
the same stream-tagged multi-tenant engine:

  * **sequential** — ``flush(final=True)`` after every tenant's submit:
    each sub-batch rides alone in a padded micro-batch (the no-router
    baseline a naive per-tenant serving loop would produce);
  * **coalesced**  — submits queue up; one flush per round packs every
    tenant's items into full micro-batches.

Claims checked (ISSUE 3 acceptance):

  * identical per-tenant pair sets from both drivers (coalescing is
    semantically free);
  * coalesced ≥ 3× items/sec with 64 low-rate tenants (non-smoke);
  * padding waste telemetry: sequential ≫ coalesced.

Results are written machine-readably to ``BENCH_runtime.json``.

``--shards P`` additionally runs the coalesced driver with the runtime on
``ShardedFacade`` over P in-process shards (forcing
``--xla_force_host_platform_device_count`` before jax initializes — the
host-platform device-count trick) and enforces that the sharded per-tenant
pair sets are identical to the single-device ones (DESIGN.md §10).

``--eviction {oldest,dead,quota}`` selects the window write-slot policy
for the coalescing comparison (DESIGN.md §11; quota splits the ring
evenly), and ``--bursty`` runs the tenant-isolation scenario: one tenant
floods at ≫10× the others' rate into a deliberately undersized ring,
under **each** policy.  Claims enforced there: the slow tenants' live-item
overflow is *lower* under ``quota`` than under ``oldest``, and under
``quota`` the slow tenants' pair sets equal the brute-force truth
(pair-set check).  ``--bursty`` writes ``BENCH_eviction.json`` by default.

``--latency`` runs the open-loop arrival scenario (DESIGN.md §12):
wall-clock Poisson arrivals replayed in real time against deadline
flushes, with per-tenant admission→emission latency percentiles read off
the metrics registry's log-bucket histograms.  Writes
``BENCH_latency.json`` (including the raw global histogram).

Standalone usage (CI smoke runs these):

    PYTHONPATH=src python -m benchmarks.runtime_throughput --smoke
    PYTHONPATH=src python -m benchmarks.runtime_throughput --smoke --shards 2
    PYTHONPATH=src python -m benchmarks.runtime_throughput --smoke --bursty
    PYTHONPATH=src python -m benchmarks.runtime_throughput --smoke --latency
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

# host-platform device-count trick: must land in the environment BEFORE
# jax initializes (which the repro imports below trigger), so sniff argv
# here rather than waiting for argparse (both --shards N and --shards=N;
# malformed values are left for argparse to reject properly)
def _sniff_shards(argv) -> int:
    for i, a in enumerate(argv):
        v = None
        if a == "--shards" and i + 1 < len(argv):
            v = argv[i + 1]
        elif a.startswith("--shards="):
            v = a.split("=", 1)[1]
        if v is not None:
            try:
                return int(v)
            except ValueError:
                return 1
    return 1


_n = _sniff_shards(sys.argv)
if _n > 1 and "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}"
    ).strip()

import numpy as np

from repro.data.synth import bursty_tenant_traffic, dense_embedding_stream
from repro.engine import EngineConfig, quota_partition
from repro.runtime import MultiTenantRuntime, ShardedFacade, TenantTable

from .common import Row

JSON_PATH = "BENCH_runtime.json"
BURSTY_JSON_PATH = "BENCH_eviction.json"
LATENCY_JSON_PATH = "BENCH_latency.json"


def _traffic(n_tenants, rounds, per_round, d, seed=0):
    """Interleaved multi-tenant traffic: per-tenant near-dup streams,
    globally time-ordered rounds."""
    streams = [
        dense_embedding_stream(rounds * per_round, d, seed=seed + k, rate=4.0)
        for k in range(n_tenants)
    ]
    # one global clock: round r spans [r, r+1); tenants jitter inside it
    rng = np.random.default_rng(seed + 999)
    order = [rng.permutation(n_tenants) for _ in range(rounds)]
    events = []
    for r in range(rounds):
        for j, k in enumerate(order[r]):
            lo = r * per_round
            ts = r + (j + rng.random(per_round) * 0.5) / (n_tenants + 1)
            events.append((k, streams[k][0][lo:lo + per_round], np.sort(ts)))
    return events


def _run(events, cfg, table, span, coalesce: bool, engine=None):
    rt = MultiTenantRuntime(cfg, table, span=span,
                            max_queue_per_tenant=1 << 20, engine=engine)
    t0 = time.perf_counter()
    last_round_start = 0
    for i, (k, vecs, ts) in enumerate(events):
        rt.submit(int(k), vecs, ts)
        if not coalesce:
            rt.flush(final=True)
        elif i - last_round_start + 1 >= table.n_tenants:
            rt.flush()                      # once per round: pack the queue
            last_round_start = i + 1
    rt.flush(final=True)
    per = rt.drain_by_tenant()
    elapsed = time.perf_counter() - t0
    pairs_per_tenant = [
        set(zip(per[k][0].tolist(), per[k][1].tolist()))
        for k in range(table.n_tenants)
    ]
    return rt, elapsed, pairs_per_tenant


def run(
    fast: bool = True, smoke: bool = False, shards: int = 1,
    eviction: str = "oldest",
) -> List[Row]:
    rows: List[Row] = []
    if smoke:
        n_tenants, rounds, per_round, d, mb, cap = 8, 4, 4, 32, 32, 512
    elif fast:
        n_tenants, rounds, per_round, d, mb, cap = 64, 8, 4, 64, 128, 4096
    else:
        n_tenants, rounds, per_round, d, mb, cap = 64, 24, 4, 64, 128, 8192
    span = 2 if smoke else 4
    theta, lam = 0.8, 0.5
    rows.append(Row("runtime/smoke_mode", float(smoke)))
    rows.append(Row("runtime/n_tenants", float(n_tenants)))
    rows.append(Row("runtime/items_per_submit", float(per_round)))
    rows.append(Row("runtime/shards", float(shards)))
    rows.append(Row("runtime/eviction_" + eviction, 1.0))

    table = TenantTable.uniform(n_tenants, theta, lam)
    quotas = (
        quota_partition(cap, [1.0] * n_tenants)
        if eviction == "quota" else None
    )
    cfg = EngineConfig(
        theta=theta, lam=lam, capacity=cap, d=d, micro_batch=mb,
        max_pairs=4096, tile_k=mb * mb, block_q=mb, block_w=mb,
        chunk_d=min(d, 128), eviction=eviction, quotas=quotas,
    )
    n_items = n_tenants * rounds * per_round
    events = _traffic(n_tenants, rounds, per_round, d)

    # warmup both drivers (jit compile), then timed runs on fresh runtimes
    warm = events[: 2 * n_tenants]
    _run(warm, cfg, table, span, coalesce=True)
    _run(warm[: n_tenants], cfg, table, span, coalesce=False)

    rt_c, t_coal, pairs_c = _run(events, cfg, table, span, True)
    rt_s, t_seq, pairs_s = _run(events, cfg, table, span, False)

    match = pairs_c == pairs_s
    total_pairs = sum(len(p) for p in pairs_c)
    rows.append(Row("runtime/pair_sets_match", float(match),
                    f"{total_pairs} pairs, {n_tenants} tenants"))
    rows.append(Row("runtime/coalesced/items_per_s", n_items / t_coal,
                    f"{t_coal*1e3:.0f} ms for {n_items} items"))
    rows.append(Row("runtime/sequential/items_per_s", n_items / t_seq,
                    f"{t_seq*1e3:.0f} ms"))
    rows.append(Row("runtime/coalescing_speedup_x", t_seq / t_coal,
                    f"{n_tenants} tenants × {per_round}-item submits"))
    sc, ss = rt_c.stats(), rt_s.stats()
    rows.append(Row("runtime/coalesced/padding_waste", sc["padding_waste"],
                    f"{sc['padded_rows']} inert rows"))
    rows.append(Row("runtime/sequential/padding_waste", ss["padding_waste"],
                    f"{ss['padded_rows']} inert rows"))
    rows.append(Row("runtime/coalesced/spans", float(sc["spans_dispatched"])))
    rows.append(Row("runtime/sequential/spans", float(ss["spans_dispatched"])))
    rows.append(Row("runtime/pairs_dropped",
                    float(rt_c.pairs_dropped + rt_s.pairs_dropped)))
    rows.append(Row("runtime/window_overflow",
                    float(rt_c.overflow + rt_s.overflow)))
    rows.append(Row("runtime/queue_delay_mean_s", sc["queue_delay_mean_s"],
                    "coalesced admission → dispatch"))

    if shards > 1:
        # multi-tenant × sharded (DESIGN.md §10): same coalesced traffic,
        # runtime on ShardedFacade over P in-process shards — identical
        # per-tenant pair sets are a hard claim, throughput is informative
        import jax

        if jax.device_count() < shards:
            raise RuntimeError(
                f"--shards {shards} needs ≥{shards} devices; found "
                f"{jax.device_count()} (XLA_FLAGS device-count trick "
                f"not applied?)"
            )
        mesh = jax.make_mesh((shards,), ("data",))
        scfg = EngineConfig(
            theta=theta, lam=lam, capacity=cap // shards, d=d,
            micro_batch=mb, max_pairs=4096, tile_k=mb * mb, block_q=mb,
            block_w=mb, chunk_d=min(d, 128), eviction=eviction,
            quotas=None if quotas is None
            else quota_partition(cap // shards, [1.0] * n_tenants),
        )
        _run(warm, scfg, table, span, True, engine=ShardedFacade(mesh))
        rt_sh, t_sh, pairs_sh = _run(
            events, scfg, table, span, True, engine=ShardedFacade(mesh)
        )
        rows.append(Row("runtime/sharded/pair_sets_match_single",
                        float(pairs_sh == pairs_c), f"{shards} shards"))
        rows.append(Row("runtime/sharded/items_per_s", n_items / t_sh,
                        f"{t_sh*1e3:.0f} ms, {shards} host shards"))
        rows.append(Row("runtime/sharded/pairs_dropped",
                        float(rt_sh.pairs_dropped)))
        rows.append(Row("runtime/sharded/window_overflow",
                        float(rt_sh.overflow)))
        ssh = rt_sh.stats()
        rows.append(Row("runtime/sharded/live_slots_max",
                        float(max(ssh["shards"]["live_slots"])),
                        "per-shard ring liveness"))
    return rows


def _slow_truth(per_tenant, theta, lam):
    """Per-slow-tenant brute-force pair sets in local index space."""
    out = []
    for vecs, ts in per_tenant[1:]:
        dec = (vecs @ vecs.T) * np.exp(-lam * np.abs(ts[:, None] - ts[None, :]))
        n = vecs.shape[0]
        out.append({
            (j, i) for i in range(n) for j in range(i) if dec[i, j] >= theta
        })
    return out


def run_bursty(smoke: bool = False, shards: int = 1) -> List[Row]:
    """Tenant-isolation scenario: the identical bursty traffic under every
    eviction policy; per-policy slow-tenant overflow and pair recall."""
    rows: List[Row] = []
    if smoke:
        n_slow, rounds, burst, d, mb, cap = 3, 8, 45, 32, 16, 32
    else:
        # per-round arrivals (burst + n_slow) must exceed capacity plus the
        # micro-batch ingest lag (cap + mb − 1) so oldest-first reliably
        # evicts the slow tenants' previous round
        n_slow, rounds, burst, d, mb, cap = 7, 20, 150, 64, 32, 96
    k_total = n_slow + 1
    th_slow, lam_slow = 0.8, 0.1
    table = TenantTable(
        [0.9] + [th_slow] * n_slow, [2.0] + [lam_slow] * n_slow
    )
    submits, per_tenant = bursty_tenant_traffic(n_slow, rounds, burst, d,
                                                seed=11)
    truth = _slow_truth(per_tenant, th_slow, lam_slow)
    n_true = sum(len(t) for t in truth)
    engine = None
    if shards > 1:
        import jax

        engine = ShardedFacade(jax.make_mesh((shards,), ("data",)))
    rows.append(Row("bursty/smoke_mode", float(smoke)))
    rows.append(Row("bursty/shards", float(shards)))
    rows.append(Row("bursty/n_slow_tenants", float(n_slow)))
    rows.append(Row("bursty/burst_per_round", float(burst)))
    rows.append(Row("bursty/true_slow_pairs", float(n_true)))

    for eviction in ("oldest", "dead", "quota"):
        quotas = (
            quota_partition(cap // shards, [1.0] * k_total)
            if eviction == "quota" else None
        )
        cfg = EngineConfig(
            theta=th_slow, lam=lam_slow, capacity=cap // shards, d=d,
            micro_batch=mb, max_pairs=8192, tile_k=mb * mb, block_q=mb,
            block_w=mb, chunk_d=min(d, 128), join_impl="scan",
            eviction=eviction, quotas=quotas,
        )
        rt = MultiTenantRuntime(cfg, table, span=2,
                                max_queue_per_tenant=1 << 20, engine=engine)
        local_of = [dict() for _ in range(k_total)]
        counts = [0] * k_total
        t0 = time.perf_counter()
        for k, v, t in submits:
            for u in rt.submit(k, v, t).tolist():
                local_of[k][u] = counts[k]
                counts[k] += 1
        rt.flush(final=True)
        per = rt.drain_by_tenant()
        elapsed = time.perf_counter() - t0
        got = []
        for k in range(1, k_total):
            ua, ub = per[k][0], per[k][1]
            got.append({
                tuple(sorted((local_of[k][a], local_of[k][b])))
                for a, b in zip(ua.tolist(), ub.tolist())
            })
        s = rt.stats()
        by = s["window_overflow_by_tenant"]
        slow_ovf = sum(by[1:])
        recall = sum(len(g & t) for g, t in zip(got, truth)) / max(n_true, 1)
        exact = all(g == t for g, t in zip(got, truth))
        p = f"bursty/{eviction}"
        rows.append(Row(f"{p}/slow_overflow", float(slow_ovf),
                        f"bursty tenant lost {by[0]} of its own"))
        rows.append(Row(f"{p}/bursty_overflow", float(by[0])))
        rows.append(Row(f"{p}/overflow_by_tenant_sums", float(
            sum(by) == s["window_overflow"]
        )))
        rows.append(Row(f"{p}/slow_pair_recall", recall,
                        f"{n_true} true pairs over {n_slow} slow tenants"))
        rows.append(Row(f"{p}/slow_pairs_exact", float(exact)))
        rows.append(Row(f"{p}/items_per_s", s["n_items"] / elapsed,
                        f"{elapsed*1e3:.0f} ms for {s['n_items']} items"))
    return rows


def _hist_delta(final: dict, base: dict) -> dict:
    """Snapshot-form histogram delta (observations between two snapshots)."""
    counts = [b - a for a, b in zip(base["counts"], final["counts"])]
    return {
        "bounds": final["bounds"],
        "counts": counts,
        "sum": final["sum"] - base["sum"],
        "count": final["count"] - base["count"],
    }


def run_latency(smoke: bool = False):
    """Open-loop arrival scenario: admission→emission latency histograms.

    Arrivals are scheduled on a wall clock (Poisson per tenant) and
    replayed in real time; the runtime flushes on a fixed deadline
    (``flush(final=True)``, the latency-deadline case), so each item's
    latency = queueing until its deadline flush + device scan + D2H copy
    landing on the host.  Percentiles come from the registry's log-bucket
    histograms (``latency/admit_to_emit_s``, ``tenant/<k>/latency_s``) —
    the same metrics a scraper would see — with warmup observations
    subtracted via a baseline snapshot.

    Returns ``(rows, latency_histogram)`` — the delta histogram rides
    into ``BENCH_latency.json`` for offline analysis.
    """
    from repro.obs import histogram_percentile

    rows: List[Row] = []
    if smoke:
        n_tenants, horizon_s, rate, d, mb, cap = 4, 0.6, 400.0, 32, 16, 512
        deadline_s = 0.02
    else:
        n_tenants, horizon_s, rate, d, mb, cap = 16, 3.0, 1000.0, 64, 64, 4096
        deadline_s = 0.01
    theta, lam = 0.8, 0.5
    rng = np.random.default_rng(7)
    # per-tenant Poisson arrivals over the horizon, merged into one
    # globally time-ordered open-loop schedule
    events = []
    for k in range(n_tenants):
        vecs, _ = dense_embedding_stream(
            int(rate * horizon_s), d, seed=100 + k, rate=4.0
        )
        t, i = 0.0, 0
        while True:
            t += rng.exponential(n_tenants / rate)
            if t >= horizon_s or i >= vecs.shape[0]:
                break
            events.append((t, k, vecs[i]))
            i += 1
    events.sort(key=lambda e: e[0])

    table = TenantTable.uniform(n_tenants, theta, lam)
    cfg = EngineConfig(
        theta=theta, lam=lam, capacity=cap, d=d, micro_batch=mb,
        max_pairs=8192, tile_k=mb * mb, block_q=mb, block_w=mb,
        chunk_d=min(d, 128),
    )
    rt = MultiTenantRuntime(cfg, table, span=2, max_queue_per_tenant=1 << 20)
    # warmup: one dispatch + drain compiles the (fixed-shape) step; the
    # baseline snapshot subtracts its latency observations afterwards
    warm = np.zeros((mb, d), np.float32)
    warm[:, 0] = 1.0
    rt.submit(0, warm, np.full(mb, -1e6))
    rt.flush(final=True)
    rt.drain_by_tenant()
    base = rt.registry.snapshot()

    t0 = time.perf_counter()
    next_deadline = deadline_s
    for t_sched, k, vec in events:
        now = time.perf_counter() - t0
        if t_sched > now:
            time.sleep(t_sched - now)
            now = t_sched
        while now >= next_deadline:
            rt.flush(final=True)
            next_deadline += deadline_s
            now = time.perf_counter() - t0
        rt.submit(int(k), vec[None, :], np.asarray([t_sched]))
    rt.flush(final=True)
    rt.drain_by_tenant()                 # pops records → observes latency
    snap = rt.registry.snapshot()

    hist = _hist_delta(snap["latency/admit_to_emit_s"],
                       base["latency/admit_to_emit_s"])
    rows.append(Row("latency/smoke_mode", float(smoke)))
    rows.append(Row("latency/n_tenants", float(n_tenants)))
    rows.append(Row("latency/deadline_ms", deadline_s * 1e3))
    rows.append(Row("latency/items", float(len(events)),
                    f"open loop over {horizon_s}s"))
    rows.append(Row("latency/observed", float(hist["count"])))
    rows.append(Row("latency/p50_ms",
                    histogram_percentile(hist, 0.50) * 1e3))
    rows.append(Row("latency/p99_ms",
                    histogram_percentile(hist, 0.99) * 1e3))
    rows.append(Row("latency/mean_ms",
                    hist["sum"] / max(hist["count"], 1) * 1e3))
    for k in range(n_tenants):
        th = _hist_delta(snap[f"tenant/{k}/latency_s"],
                         base[f"tenant/{k}/latency_s"])
        rows.append(Row(f"latency/tenant/{k}/observed", float(th["count"])))
        rows.append(Row(f"latency/tenant/{k}/p50_ms",
                        histogram_percentile(th, 0.50) * 1e3))
        rows.append(Row(f"latency/tenant/{k}/p99_ms",
                        histogram_percentile(th, 0.99) * 1e3))
    for stage in ("admit", "coalesce", "h2d", "scan", "drain", "emit"):
        rows.append(Row(f"latency/span/{stage}/time_s",
                        snap[f"span/{stage}/time_s"],
                        f"{snap[f'span/{stage}/calls']} calls"))
    return rows, hist


def check_latency(rows: List[Row]) -> List[str]:
    by = {r.name: r.value for r in rows}
    problems = []
    n_items = by.get("latency/items", 0.0)
    if by.get("latency/observed") != n_items or n_items == 0.0:
        problems.append(
            f"latency histogram observed {by.get('latency/observed')} of "
            f"{n_items} admitted items"
        )
    p50, p99 = by.get("latency/p50_ms", 0.0), by.get("latency/p99_ms", 0.0)
    if not 0.0 < p50 <= p99:
        problems.append(f"degenerate percentiles (p50={p50}, p99={p99})")
    k = 0
    while f"latency/tenant/{k}/observed" in by:
        if by[f"latency/tenant/{k}/observed"] == 0.0 or \
                by[f"latency/tenant/{k}/p50_ms"] <= 0.0:
            problems.append(f"tenant {k}: latency histogram not populated")
        k += 1
    if k == 0:
        problems.append("no per-tenant latency histograms in output")
    return problems


def check_bursty(rows: List[Row]) -> List[str]:
    by = {r.name: r.value for r in rows}
    problems = []
    for ev in ("oldest", "dead", "quota"):
        if by.get(f"bursty/{ev}/overflow_by_tenant_sums") != 1.0:
            problems.append(
                f"{ev}: window_overflow_by_tenant does not sum to "
                f"window_overflow"
            )
    if by.get("bursty/quota/slow_overflow", 1.0) >= \
            by.get("bursty/oldest/slow_overflow", 0.0):
        problems.append(
            "quota eviction did not lower slow-tenant overflow vs oldest "
            f"({by.get('bursty/quota/slow_overflow')} vs "
            f"{by.get('bursty/oldest/slow_overflow')})"
        )
    if by.get("bursty/quota/slow_pairs_exact") != 1.0:
        problems.append(
            "quota: within-quota tenants did not emit their exact truth "
            f"(recall {by.get('bursty/quota/slow_pair_recall'):.3f})"
        )
    if by.get("bursty/oldest/slow_pair_recall", 1.0) >= 1.0:
        problems.append(
            "bursty scenario is vacuous: oldest-first lost no slow pairs"
        )
    return problems


def check(rows: List[Row]) -> List[str]:
    by = {r.name: r.value for r in rows}
    problems = []
    if by.get("runtime/pair_sets_match") != 1.0:
        problems.append("coalesced and sequential drivers emit different pairs")
    if by.get("runtime/pairs_dropped", 0.0) != 0.0:
        problems.append("emission overflowed on the benchmark traffic")
    if by.get("runtime/window_overflow", 0.0) != 0.0:
        problems.append("ring window overflowed on the benchmark traffic")
    waste_s = by.get("runtime/sequential/padding_waste", 0.0)
    waste_c = by.get("runtime/coalesced/padding_waste", 1.0)
    if waste_c >= waste_s:
        problems.append(
            f"coalescing did not cut padding waste "
            f"({waste_c:.2f} vs {waste_s:.2f})"
        )
    if not by.get("runtime/smoke_mode") and \
            by.get("runtime/coalescing_speedup_x", 0.0) < 3.0:
        problems.append(
            "coalescing under the claimed 3× vs sequential per-tenant "
            f"pushes ({by.get('runtime/coalescing_speedup_x'):.2f}×)"
        )
    if by.get("runtime/shards", 1.0) > 1.0:
        if by.get("runtime/sharded/pair_sets_match_single") != 1.0:
            problems.append(
                "sharded runtime emitted different per-tenant pairs than "
                "the single-device runtime"
            )
        if by.get("runtime/sharded/pairs_dropped", 0.0) != 0.0:
            problems.append("sharded emission overflowed on benchmark traffic")
        if by.get("runtime/sharded/window_overflow", 0.0) != 0.0:
            problems.append("sharded ring window overflowed on benchmark traffic")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI): exercises both drivers, relaxes "
                         "the wall-clock claim")
    ap.add_argument("--full", action="store_true", help="longer streams")
    ap.add_argument("--shards", type=int, default=1,
                    help="also run the coalesced driver on ShardedFacade "
                         "over this many in-process shards (forces host "
                         "platform devices before jax init)")
    ap.add_argument("--eviction", choices=["oldest", "dead", "quota"],
                    default="oldest",
                    help="window write-slot policy for the coalescing "
                         "comparison (DESIGN.md §11)")
    ap.add_argument("--bursty", action="store_true",
                    help="run the bursty-tenant isolation scenario instead: "
                         "identical flood traffic under each eviction "
                         "policy; enforces lower slow-tenant overflow and "
                         "exact slow pair sets under quota")
    ap.add_argument("--latency", action="store_true",
                    help="run the open-loop arrival scenario instead: "
                         "wall-clock Poisson arrivals, deadline flushes, "
                         "per-tenant admission→emission latency histograms "
                         "from the metrics registry (DESIGN.md §12)")
    ap.add_argument("--json", default=None,
                    help=f"machine-readable output path (default {JSON_PATH}; "
                         f"{BURSTY_JSON_PATH} with --bursty, "
                         f"{LATENCY_JSON_PATH} with --latency)")
    args = ap.parse_args()
    if args.bursty and args.latency:
        ap.error("--bursty and --latency are mutually exclusive scenarios")
    json_path = args.json or (
        BURSTY_JSON_PATH if args.bursty
        else LATENCY_JSON_PATH if args.latency
        else JSON_PATH
    )
    t0 = time.time()
    latency_hist = None
    if args.bursty:
        benchmark = "runtime_throughput_bursty"
        rows = run_bursty(smoke=args.smoke, shards=args.shards)
        problems = check_bursty(rows)
    elif args.latency:
        benchmark = "runtime_latency"
        rows, latency_hist = run_latency(smoke=args.smoke)
        problems = check_latency(rows)
    else:
        benchmark = "runtime_throughput"
        rows = run(fast=not args.full, smoke=args.smoke, shards=args.shards,
                   eviction=args.eviction)
        problems = check(rows)
    print("name,value,extra")
    for r in rows:
        print(r.csv())
    payload = {
        "benchmark": benchmark,
        "mode": "smoke" if args.smoke else ("fast" if not args.full else "full"),
        "shards": args.shards,
        "eviction": "all" if args.bursty else args.eviction,
        "elapsed_s": round(time.time() - t0, 3),
        "rows": [dict(name=r.name, value=r.value, extra=r.extra) for r in rows],
        "problems": problems,
    }
    if latency_hist is not None:
        payload["latency_histogram"] = latency_hist
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {json_path} ({len(rows)} rows) in {payload['elapsed_s']}s")
    for p in problems:
        print(f"# CLAIM-FAIL {p}")
    sys.exit(1 if problems else 0)


if __name__ == "__main__":
    main()
