"""Benchmark harness: one module per paper table/figure + beyond-paper.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--full]

Each module exposes ``run(fast) → [Row]`` and ``check(rows) → [problem]``;
the harness prints ``name,value,extra`` CSV and a claim-validation summary,
exiting non-zero if any paper claim fails to reproduce.
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (
    engine_throughput, fig2_entries_ratio, fig34_mb_vs_str, fig56_indexes,
    fig789_params, kernel_bench, roofline_table, table2_completion,
    tile_pruning,
)

MODULES = [
    ("table2_completion", table2_completion),
    ("fig2_entries_ratio", fig2_entries_ratio),
    ("fig34_mb_vs_str", fig34_mb_vs_str),
    ("fig56_indexes", fig56_indexes),
    ("fig789_params", fig789_params),
    ("tile_pruning", tile_pruning),
    ("kernel_bench", kernel_bench),
    ("engine_throughput", engine_throughput),
    ("roofline_table", roofline_table),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger scales (slower, closer to the paper's)")
    ap.add_argument("--only", help="run a single module by name")
    args = ap.parse_args()

    fast = not args.full
    all_problems = []
    print("name,value,extra")
    for name, mod in MODULES:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        rows = mod.run(fast=fast)
        for r in rows:
            print(r.csv())
        problems = mod.check(rows)
        status = "OK" if not problems else f"{len(problems)} CLAIM FAILURES"
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s — {status}")
        for p in problems:
            print(f"#   CLAIM-FAIL {p}")
        all_problems.extend(problems)
    print(f"# TOTAL: {'all paper claims reproduced' if not all_problems else all_problems}")
    sys.exit(1 if all_problems else 0)


if __name__ == "__main__":
    main()
