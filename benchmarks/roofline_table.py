"""Render the §Roofline table from the dry-run sweep JSONs.

Reads ``results/dryrun/*.json`` (written by ``repro.launch.dryrun --all``)
and emits the per-(arch × shape × mesh) three-term roofline table as
markdown — the artifact EXPERIMENTS.md §Roofline embeds."""

from __future__ import annotations

import json
import pathlib
from typing import List, Optional

from .common import Row

_DIR = pathlib.Path("results/dryrun")


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f} s"
    if x >= 1e-3:
        return f"{x*1e3:.2f} ms"
    return f"{x*1e6:.0f} µs"


def load_records(directory: pathlib.Path = _DIR) -> List[dict]:
    recs = []
    for p in sorted(directory.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def render_markdown(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute | memory | collective | bound | "
        "MODEL/HLO | args GiB | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | — | — | — | "
                f"skip | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | — | — | — | "
                f"ERROR | — | — | — |"
            )
            continue
        t = r["roofline"]
        mem = r.get("memory", {})
        args = mem.get("argument_size_in_bytes", 0) / 2 ** 30
        temp = mem.get("temp_size_in_bytes", 0) / 2 ** 30
        ur = r.get("useful_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {_fmt_s(t['compute_s'])} | {_fmt_s(t['memory_s'])} "
            f"| {_fmt_s(t['collective_s'])} | {t['dominant']} "
            f"| {ur:.3f} | {args:.2f} | {temp:.2f} |"
        )
    return "\n".join(lines)


def run(fast: bool = True) -> List[Row]:
    if not _DIR.exists():
        return [Row("roofline/available", 0.0, "run repro.launch.dryrun --all")]
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    rows = [Row("roofline/cells_ok", float(len(ok)), f"of {len(recs)}")]
    out = pathlib.Path("results/roofline_table.md")
    out.write_text(render_markdown(recs) + "\n")
    rows.append(Row("roofline/table_written", 1.0, str(out)))
    for r in ok:
        t = r["roofline"]
        mesh = "mp" if r.get("multi_pod") else "sp"
        rows.append(
            Row(
                f"roofline/{r['arch']}/{r['shape']}/{mesh}/bound_s",
                t["bound_s"], t["dominant"],
            )
        )
    return rows


def check(rows: List[Row]) -> List[str]:
    by = {r.name: r for r in rows}
    cells = by.get("roofline/cells_ok")
    if cells is None or cells.value < 1:
        return ["roofline: no dry-run records — run repro.launch.dryrun --all"]
    return []
