"""Paper Figs. 7–9: STR-L2 time vs λ (Fig. 7), vs θ (Fig. 8), and the
linearity of time in the horizon τ (Fig. 9).

Claim (Fig. 9): wall time is ≈ linear in τ = λ⁻¹ log θ⁻¹ — both parameters
act through the horizon; we report the least-squares R² over the pooled
(τ, time) points."""

from __future__ import annotations

import math
from typing import List

import numpy as np

from repro.data.synth import synthetic_stream

from .common import BENCH_SPECS, Row, run_config


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    ds = "rcv1"
    items = synthetic_stream(BENCH_SPECS[ds], seed=5)
    thetas = (0.5, 0.7, 0.9) if fast else (0.5, 0.6, 0.7, 0.8, 0.9, 0.99)
    lams = (0.01, 0.03, 0.1, 0.3) if fast else (0.01, 0.03, 0.1, 0.3, 1.0)
    taus, times = [], []
    for th in thetas:
        for lam in lams:
            # best-of-3 to suppress single-core timer noise (the paper
            # averages 3 runs after a warm-up pass)
            secs = None
            for _ in range(3):
                s, _, _ = run_config(items, "STR", "L2", th, lam,
                                     timeout_s=60.0)
                if s is not None:
                    secs = s if secs is None else min(secs, s)
            if secs is None:
                continue
            tau = math.log(1 / th) / lam
            taus.append(tau)
            times.append(secs)
            rows.append(Row(f"fig78/{ds}/theta={th}/lam={lam}/time_s", secs,
                            f"tau={tau:.2f}"))
    # Fig. 9: linear regression time ~ a·τ + b
    t = np.array(taus)
    y = np.array(times)
    A = np.stack([t, np.ones_like(t)], 1)
    coef, res, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    rows.append(Row(f"fig9/{ds}/tau_linearity_r2", r2,
                    f"slope={coef[0]:.4g} n={len(taus)}"))
    return rows


def check(rows: List[Row]) -> List[str]:
    problems = []
    by = {r.name: (r.value, r.extra) for r in rows}
    r2 = by.get("fig9/rcv1/tau_linearity_r2")
    if r2 and r2[0] < 0.7:
        problems.append(f"fig9: time not ~linear in tau (R²={r2[0]:.3f})")
    # Figs. 7/8 monotonicity: for fixed θ, larger λ (smaller τ) is faster
    import collections
    series = collections.defaultdict(list)
    for r in rows:
        if r.name.startswith("fig78/"):
            parts = dict(p.split("=") for p in r.name.split("/")[2:4])
            series[float(parts["theta"])].append((float(parts["lam"]), r.value))
    for th, pts in series.items():
        pts.sort()
        for (l1, t1), (l2, t2) in zip(pts, pts[1:]):
            if t2 > t1 * 1.5:    # generous slack for timer noise
                problems.append(
                    f"fig7: time grew with λ at θ={th}: {t1:.2f}@{l1} → "
                    f"{t2:.2f}@{l2}"
                )
    return problems
