"""Paper Figs. 5–6: STR with different indexes — wall time (Fig. 5) and
entries traversed (Fig. 6) as functions of θ.

Claims: L2 is (almost always) the fastest; INV competitive only at short
horizons; L2AP's re-indexing makes it traverse *more* entries than L2 as
the horizon shrinks (it loses the ordered-list truncation fast path)."""

from __future__ import annotations

from typing import List

from repro.data.synth import synthetic_stream

from .common import BENCH_SPECS, Row, run_config

THETAS = (0.5, 0.7, 0.9)
INDEXES = ("INV", "L2AP", "L2")


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    ds = "rcv1"
    items = synthetic_stream(BENCH_SPECS[ds], seed=4)
    lams = (0.03,) if fast else (0.01, 0.1, 1.0)
    for lam in lams:
        for th in THETAS:
            for idx in INDEXES:
                secs, c, _ = run_config(items, "STR", idx, th, lam,
                                        timeout_s=60.0)
                rows.append(
                    Row(f"fig5/{ds}/lam={lam}/theta={th}/{idx}/time_s",
                        -1.0 if secs is None else secs)
                )
                rows.append(
                    Row(f"fig6/{ds}/lam={lam}/theta={th}/{idx}/entries",
                        float(c.entries_traversed),
                        f"reindex_entries={c.reindex_entries}")
                )
    return rows


def check(rows: List[Row]) -> List[str]:
    problems = []
    by = {r.name: r.value for r in rows}
    for name, v in list(by.items()):
        if "/L2/entries" in name:
            inv = by.get(name.replace("/L2/", "/INV/"))
            if inv is not None and v > inv * 1.02:
                problems.append(f"{name}: L2 traverses more than INV")
    # L2 should never lose badly to L2AP in time (paper: L2 ≤ L2AP)
    for name, v in list(by.items()):
        if "/L2/time_s" in name and v > 0:
            l2ap = by.get(name.replace("/L2/", "/L2AP/"))
            if l2ap is not None and l2ap > 0 and v > l2ap * 2.0:
                problems.append(f"{name}: L2 {v:.2f}s ≫ L2AP {l2ap:.2f}s")
    return problems
