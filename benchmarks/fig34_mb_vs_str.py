"""Paper Figs. 3–4: wall time, MB vs STR, as a function of θ (per λ).

Claims reproduced qualitatively: STR beats MB on the sparse sequential
dataset (RCV1-like), most clearly at low θ; on the dense dataset
(WebSpam-like) MB is competitive or ahead at large λ — density makes STR's
per-item lazy pruning of many long posting lists expensive."""

from __future__ import annotations

from typing import List

from repro.data.synth import synthetic_stream

from .common import BENCH_SPECS, Row, run_config

THETAS = (0.5, 0.7, 0.9)


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    datasets = ("rcv1", "webspam")
    lams = (0.03, 0.3) if fast else (0.01, 0.1, 1.0)
    for ds in datasets:
        items = synthetic_stream(BENCH_SPECS[ds], seed=3)
        for lam in lams:
            for th in THETAS:
                for fw in ("MB", "STR"):
                    secs, _, n = run_config(items, fw, "L2", th, lam,
                                            timeout_s=60.0)
                    rows.append(
                        Row(f"fig34/{ds}/lam={lam}/theta={th}/{fw}/time_s",
                            -1.0 if secs is None else secs, f"pairs={n}")
                    )
    return rows


def check(rows: List[Row]) -> List[str]:
    problems = []
    by = {r.name: r.value for r in rows}
    # RCV1-like, smallest λ (largest τ), low θ: STR should win (Fig. 3)
    for th in (0.5,):
        for lam in (0.03, 0.01):
            mb = by.get(f"fig34/rcv1/lam={lam}/theta={th}/MB/time_s")
            st = by.get(f"fig34/rcv1/lam={lam}/theta={th}/STR/time_s")
            if mb is not None and st is not None and mb > 0:
                if st > mb * 1.5:
                    problems.append(
                        f"fig34: STR {st:.2f}s ≫ MB {mb:.2f}s on rcv1 "
                        f"(θ={th}, λ={lam})"
                    )
    return problems
