"""Beyond-paper: end-to-end effectiveness of the device-resident strip
gate (DESIGN.md §13) on the streaming engine.

The gate computes, per (query-tile × window-strip), the admissible upper
bound ``min(prefix, chunk-ℓ2) · exp(-λ·Δt_min)`` from carry-resident strip
summaries and skips every tile it proves below θ — before any dot product
runs.  This benchmark drives the real engine over a topically clustered
stream (:func:`topic_drift_stream`; isotropic data defeats value bounds by
construction) and reports, from the ``engine/prune/*`` metrics:

  * **skip fraction** per (capacity, θ, λ) — must grow with capacity at
    fixed (θ, λ): a larger window holds more stale topics whose strips
    the value bound kills (and, at λ > 0, more expired history);
  * **non-vacuity** — some but not all tiles are skipped (a gate that
    skips nothing is dead weight; one that skips everything is either
    broken or the stream is degenerate);
  * **items/sec, gate on vs off** at the largest capacity — the gated
    engine must clear 1.3× the ungated one at capacity ≥ 2^16 (the
    non-smoke claim; smoke shapes only exercise the paths).

Standalone usage (CI smoke runs this):

    PYTHONPATH=src python -m benchmarks.tile_pruning --smoke
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List

from repro.data.synth import topic_drift_stream
from repro.engine import EngineConfig, StreamEngine

from .common import Row

JSON_PATH = "BENCH_prune.json"

THETAS = (0.5, 0.7)
LAMS = (0.0, 0.05)


def _drive(cfg: EngineConfig, vecs, ts, batch: int) -> StreamEngine:
    eng = StreamEngine(cfg)
    for i in range(0, vecs.shape[0], batch):
        eng.push(vecs[i : i + batch], ts[i : i + batch])
    return eng


def _skip_frac(eng: StreamEngine) -> tuple[float, float, float]:
    m = eng.metrics()
    total = max(m["engine/prune/tiles_total"], 1)
    st = m["engine/prune/tiles_skipped_time"] / total
    sl = m["engine/prune/tiles_skipped_l2"] / total
    return st + sl, st, sl


def run(fast: bool = True, smoke: bool = False) -> List[Row]:
    rows: List[Row] = []
    if smoke:
        d, mb, seg, batch = 32, 64, 128, 64
        caps = (256, 512, 1024)
        cap_speed, n_timed = 1024, 1024
    elif fast:
        d, mb, seg, batch = 64, 256, 1024, 256
        caps = (1024, 4096, 16384)
        cap_speed, n_timed = 1 << 16, 8192
    else:
        d, mb, seg, batch = 64, 256, 1024, 256
        caps = (4096, 16384, 65536)
        cap_speed, n_timed = 1 << 17, 16384
    rows.append(Row("prune/smoke_mode", float(smoke)))
    rows.append(Row("prune/capacity_speed", float(cap_speed)))

    def cfg(capacity, theta, lam, gate=None):
        return EngineConfig(
            theta=theta, lam=lam, capacity=capacity, d=d, micro_batch=mb,
            block_q=mb, block_w=mb, chunk_d=min(d, 128), tile_k=256,
            max_pairs=4096, join_impl="scan", l2_gate=gate,
        )

    # ---- skip fraction per (capacity, θ, λ) -------------------------------
    for cap in caps:
        # fixed topic geometry across capacities: a larger window retains
        # more stale topics, so the value bound has more to kill
        vecs, ts = topic_drift_stream(
            2 * cap, d, n_topics=8, seg=seg, seed=13, rate=8.0
        )
        for theta in THETAS:
            for lam in LAMS:
                eng = _drive(cfg(cap, theta, lam), vecs, ts, batch)
                frac, f_time, f_l2 = _skip_frac(eng)
                m = eng.metrics()
                rows.append(Row(
                    f"prune/cap={cap}/theta={theta}/lam={lam}/skip_frac",
                    frac,
                    f"time={f_time:.3f} l2={f_l2:.3f} "
                    f"strips_survived={m['engine/prune/strips_survived']}",
                ))

    # ---- items/sec, gate on vs off, at the largest capacity ---------------
    theta, lam = 0.7, 0.0  # λ=0: the win must come from value bounds alone
    vecs, ts = topic_drift_stream(
        cap_speed + 2 * n_timed, d, n_topics=8, seg=seg, seed=17, rate=8.0
    )
    fill_v, fill_t = vecs[:cap_speed], ts[:cap_speed]
    timed_v, timed_t = vecs[cap_speed:], ts[cap_speed:]
    rates = {}
    for label, gate in (("on", None), ("off", False)):
        eng = _drive(cfg(cap_speed, theta, lam, gate=gate),
                     fill_v, fill_t, batch)   # warmup: jit + window fill
        eng.drain_arrays()
        t0 = time.perf_counter()
        for i in range(0, timed_v.shape[0], batch):
            eng.push(timed_v[i : i + batch], timed_t[i : i + batch])
        eng.drain_arrays()   # synchronizes with the device
        dt = time.perf_counter() - t0
        rates[label] = timed_v.shape[0] / dt
        extra = f"cap={cap_speed}, {dt*1e3:.0f} ms"
        if gate is None:
            frac, f_time, f_l2 = _skip_frac(eng)
            extra += f", skip_frac={frac:.3f} (l2={f_l2:.3f})"
        rows.append(Row(f"prune/gate_{label}/items_per_s", rates[label],
                        extra))
    rows.append(Row("prune/speedup_x", rates["on"] / rates["off"],
                    f"gate on vs off at cap={cap_speed}"))
    return rows


def check(rows: List[Row]) -> List[str]:
    problems: List[str] = []
    by = {r.name: r.value for r in rows}
    smoke = bool(by.get("prune/smoke_mode"))
    caps = sorted(
        {int(r.name.split("/")[1].split("=")[1])
         for r in rows if "/skip_frac" in r.name}
    )
    for theta in THETAS:
        for lam in LAMS:
            seq = [by[f"prune/cap={c}/theta={theta}/lam={lam}/skip_frac"]
                   for c in caps]
            # monotone in capacity at fixed (θ, λ); small tolerance for
            # the λ>0 rows where expiry already saturates the skip rate
            if not all(b >= a - 0.02 for a, b in zip(seq, seq[1:])):
                problems.append(
                    f"skip fraction not monotone in capacity at "
                    f"θ={theta} λ={lam}: {seq}"
                )
    fracs = [v for k, v in by.items() if k.endswith("/skip_frac")]
    if not any(0.0 < v < 1.0 for v in fracs):
        problems.append(f"gate vacuous on every cell: {fracs}")
    if max(fracs) <= 0.0:
        problems.append("gate never skipped a tile")
    if min(fracs) >= 1.0:
        problems.append("gate skipped every tile (degenerate stream)")
    if not smoke:
        if by.get("prune/capacity_speed", 0.0) < (1 << 16):
            problems.append("speedup not measured at capacity ≥ 2^16")
        if by.get("prune/speedup_x", 0.0) < 1.3:
            problems.append(
                f"gated engine under 1.3× ungated at capacity "
                f"{by.get('prune/capacity_speed'):.0f} "
                f"({by.get('prune/speedup_x'):.2f}×)"
            )
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI): exercises every path, relaxes "
                         "the wall-clock claim")
    ap.add_argument("--full", action="store_true", help="paper-scale shapes")
    ap.add_argument("--json", default=JSON_PATH,
                    help=f"machine-readable output path (default {JSON_PATH})")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(fast=not args.full, smoke=args.smoke)
    print("name,value,extra")
    for r in rows:
        print(r.csv())
    problems = check(rows)
    payload = {
        "benchmark": "tile_pruning",
        "mode": "smoke" if args.smoke else ("fast" if not args.full else "full"),
        "elapsed_s": round(time.time() - t0, 3),
        "rows": [dict(name=r.name, value=r.value, extra=r.extra) for r in rows],
        "problems": problems,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json} ({len(rows)} rows) in {payload['elapsed_s']}s")
    for p in problems:
        print(f"# CLAIM-FAIL {p}")
    sys.exit(1 if problems else 0)


if __name__ == "__main__":
    main()
