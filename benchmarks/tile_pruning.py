"""Beyond-paper: tile-granular pruning effectiveness of the TPU engine.

Measures the fraction of (query-tile × window-tile × d-chunk) work units
the blocked kernel actually executes, vs the dense upper bound, across θ
and λ — the TPU analogue of the paper's "entries traversed" (Figs. 2/6).
Two mechanisms: dead-tile skip (time filtering) and chunked-ℓ2 early exit."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.blocked import BlockedJoinConfig, BlockedStreamJoiner
from repro.data.synth import dense_embedding_stream

from .common import Row


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n, d = (512, 256) if fast else (2048, 512)
    vecs, ts = dense_embedding_stream(n, d, seed=7, rate=1.0, dup_frac=0.1)
    for theta in (0.5, 0.8, 0.95):
        for lam in (0.01, 0.1, 1.0):
            cfg = BlockedJoinConfig(theta=theta, lam=lam, capacity=n, d=d,
                                    block_q=64, block_w=64, chunk_d=64)
            bj = BlockedStreamJoiner(cfg)
            step = 64
            for i in range(0, n, step):
                bj.push(vecs[i:i + step], ts[i:i + step])
            max_chunks = d // cfg.chunk_d
            frac = bj.chunks_executed / max(bj.tiles_total * max_chunks, 1)
            rows.append(
                Row(f"tile_pruning/theta={theta}/lam={lam}/work_frac", frac,
                    f"chunks={bj.chunks_executed}/{bj.tiles_total * max_chunks}")
            )
    return rows


def check(rows: List[Row]) -> List[str]:
    problems = []
    by = {r.name: r.value for r in rows}
    # larger λ (shorter horizon) must prune at least as much work
    for theta in (0.5, 0.8, 0.95):
        seq = [by[f"tile_pruning/theta={theta}/lam={lam}/work_frac"]
               for lam in (0.01, 0.1, 1.0)]
        if not (seq[2] <= seq[0] + 0.05):
            problems.append(f"tile_pruning: no time-filter benefit at θ={theta}: {seq}")
    # all fractions are real fractions
    for k, v in by.items():
        if not 0.0 <= v <= 1.0:
            problems.append(f"{k}: bad fraction {v}")
    return problems
