"""Trend detection on a bursty stream (paper application #1).

A "trend" is a burst of mutually-similar documents arriving within the
time horizon.  We synthesize a bursty stream in which three topic bursts
are planted at different times, and show that the SSSJ service (a) detects
each burst as a trending group while it is live, and (b) *forgets* old
bursts — a burst's items expire once the horizon passes, which is exactly
the paper's argument for time-dependent similarity.

    PYTHONPATH=src python examples/trend_detection.py
"""

import numpy as np

from repro.serving.service import SSSJService

rng = np.random.default_rng(0)
DIM = 128
THETA, LAM = 0.8, 0.2        # τ = λ⁻¹ ln θ⁻¹ ≈ 1.12 time units

service = SSSJService(theta=THETA, lam=LAM, dim=DIM, capacity=2048)

# three planted topics: clusters of near-identical vectors
topics = rng.standard_normal((3, DIM))
topics /= np.linalg.norm(topics, axis=1, keepdims=True)


def make_batch(t_center, topic_id=None, n=16, burst_frac=0.5):
    out = rng.standard_normal((n, DIM)).astype(np.float32)
    labels = []
    for i in range(n):
        if topic_id is not None and rng.random() < burst_frac:
            out[i] = topics[topic_id] + 0.02 * rng.standard_normal(DIM)
            labels.append(topic_id)
        else:
            labels.append(-1)
    out /= np.linalg.norm(out, axis=1, keepdims=True)
    ts = t_center + rng.random(n) * 0.1
    return out, ts, labels


schedule = [
    (0.0, 0),     # burst of topic 0 at t≈0
    (0.3, 0),
    (5.0, 1),     # topic 1 at t≈5 (topic 0 far outside the horizon now)
    (5.3, 1),
    (10.0, 2),    # topic 2 at t≈10
    (10.3, 2),
    (20.0, None), # background noise only
]

uid = 0
uid_topic = {}
for t, topic in schedule:
    batch, ts, labels = make_batch(t, topic)
    for lab in labels:
        uid_topic[uid] = lab
        uid += 1
    pairs = service.submit(batch, ts)
    live = service.trending(min_size=4)
    print(f"t={t:5.1f}  topic={topic}  pairs={len(pairs):3d}  "
          f"trending groups={len(live)}")

trends = service.trending(min_size=4)
print(f"\ndetected {len(trends)} trends")
for g in trends:
    topics_in_group = {uid_topic[u] for u in g}
    print(f"  group size {len(g):2d} → topics {topics_in_group}")
    # each trend is pure: one planted topic, no cross-burst contamination
    assert len(topics_in_group) == 1 and -1 not in topics_in_group

assert len(trends) == 3, f"expected 3 planted trends, got {len(trends)}"

# the service rides the device-resident engine: emission reaches the host
# as compacted pair buffers, not dense (B, capacity) score matrices
es = service.engine.stats()
assert es["pairs_dropped"] == 0, "max_pairs undersized for this stream"
assert es["bytes_to_host"] < es["bytes_dense_equiv"]
print(f"host↔device: {es['bytes_to_host']} B compacted "
      f"vs {es['bytes_dense_equiv']} B dense-equivalent "
      f"({es['bytes_dense_equiv'] / max(es['bytes_to_host'], 1):.1f}× saved)")
print("✓ three planted bursts detected, none merged across the horizon")
