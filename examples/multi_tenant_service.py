"""End-to-end driver: one engine serving many logical streams.

Eight tenants — each with its own similarity threshold and decay horizon —
submit tiny per-request batches that no single tenant could fill a
micro-batch with.  The multi-tenant runtime coalesces them onto one
stream-tagged device engine (DESIGN.md §9): cross-tenant pairs are masked
on device, per-tenant (θ, λ) rides a small device table, and the service
groups near-duplicates under namespaced (tenant, uid) keys.

The same traffic then replays on the **sharded** variant (DESIGN.md §10):
the identical service facade over ``ShardedFacade`` spreads the ring
window across P in-process shards (host-platform device-count trick) and
must produce the identical per-tenant groups.

The final act is the **bursty-tenant demo** (DESIGN.md §11): one tenant
floods a deliberately undersized window at ~15× the others' rate.  Under
the default oldest-first eviction the flood overwrites the slow tenants'
still-live documents and their near-duplicate repost chains fall apart;
under ``eviction="quota"`` each tenant owns a static sub-ring, the burst
can only evict its own items, and every slow tenant's chain groups stay
intact.

    PYTHONPATH=src python examples/multi_tenant_service.py
"""

import os

N_SHARDS = 2
# the device-count trick must land before jax initializes (first repro import)
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402

from repro.data.synth import bursty_tenant_traffic  # noqa: E402
from repro.runtime import TenantTable  # noqa: E402
from repro.serving import MultiTenantSSSJService  # noqa: E402

rng = np.random.default_rng(0)
K, DIM, ROUNDS, PER_SUBMIT = 8, 64, 12, 3

# strict tenants (high θ, short horizon) next to permissive ones
table = TenantTable(
    thetas=[0.95, 0.9, 0.85, 0.9, 0.95, 0.8, 0.9, 0.85],
    lams=[0.2, 0.05, 0.1, 0.02, 0.5, 0.05, 0.1, 0.2],
)

# every tenant periodically re-posts a noisy copy of its own base document
bases = rng.standard_normal((K, DIM)).astype(np.float32)
traffic = []                       # (tenant, docs, timestamps), replayable
t = 0.0
for r in range(ROUNDS):
    for k in range(K):
        docs = rng.standard_normal((PER_SUBMIT, DIM)).astype(np.float32)
        docs[0] = bases[k] + 0.01 * rng.standard_normal(DIM)
        traffic.append((k, docs, t + np.arange(PER_SUBMIT) * 1e-3))
        t += 0.01


def drive(svc):
    per_round = 0
    for k, docs, ts in traffic:
        svc.submit(k, docs, ts)
        per_round += 1
        if per_round % K == 0:
            svc.flush(final=False)  # coalesce: full micro-batches only
    svc.flush(final=True)
    return svc


svc = drive(MultiTenantSSSJService(table, dim=DIM, capacity=1024, micro_batch=32))
stats = svc.stats()
assert stats["n_items"] == K * ROUNDS * PER_SUBMIT
assert stats["pairs_dropped"] == 0
for k in range(K):
    groups = svc.duplicate_groups(k)
    # each tenant's planted repost chain groups under its OWN local uids;
    # nothing leaked across streams
    assert groups and max(len(g) for g in groups) >= ROUNDS // 2, (k, groups)
print(f"✓ {K} tenants, {stats['n_items']} documents on one engine; "
      f"padding waste {stats['padding_waste']:.1%}, "
      f"{stats['spans_dispatched']} device dispatches, "
      f"per-tenant groups e.g. tenant 0 → {svc.duplicate_groups(0)[:1]}")

# ---- sharded variant: same service, ring window over N_SHARDS shards ---- #
import jax  # noqa: E402

mesh = jax.make_mesh((N_SHARDS,), ("data",))
svc_sh = drive(MultiTenantSSSJService(
    table, dim=DIM, capacity=1024, micro_batch=32, mesh=mesh,
))
sh = svc_sh.stats()
assert sh["pairs_dropped"] == 0 and sh["n_shards"] == N_SHARDS
for k in range(K):
    assert svc_sh.duplicate_groups(k) == svc.duplicate_groups(k), k
print(f"✓ sharded: identical per-tenant groups over {N_SHARDS} shards "
      f"(per-shard live slots {sh['shards']['live_slots']}, "
      f"per-shard pairs {sh['shards']['pairs_emitted']})")

# ---- bursty-tenant demo: quota eviction keeps slow tenants intact ---- #
# tenant 0 floods BURST random documents per round into a 32-slot window;
# tenants 1..3 repost a noisy copy of their base every 1.5 time units
# (within their τ ≈ 2.2 horizon, so consecutive reposts should chain) —
# the same canonical flood stream the conformance suite and the eviction
# benchmark drive (repro.data.synth.bursty_tenant_traffic)
B_ROUNDS, BURST, B_CAP = 10, 45, 32
bursty_table = TenantTable(thetas=[0.9, 0.8, 0.8, 0.8],
                           lams=[2.0, 0.1, 0.1, 0.1])
bursty_submits, _ = bursty_tenant_traffic(3, B_ROUNDS, BURST, DIM)


def drive_bursty(svc):
    for k, docs, ts in bursty_submits:
        svc.submit(k, docs, ts)
    svc.flush(final=True)
    return svc


svc_old = drive_bursty(MultiTenantSSSJService(
    bursty_table, dim=DIM, capacity=B_CAP, micro_batch=16,
))                                               # eviction="oldest" default
svc_quo = drive_bursty(MultiTenantSSSJService(
    bursty_table, dim=DIM, capacity=B_CAP, micro_batch=16,
    eviction="quota",                            # equal split: 8 slots each
))
so, sq = svc_old.stats(), svc_quo.stats()
for k in (1, 2, 3):
    # quota: the whole repost chain survives as one group per tenant …
    assert svc_quo.duplicate_groups(k) == [list(range(B_ROUNDS))], k
    # … while oldest-first broke the chain (the flood evicted live reposts)
    assert svc_old.duplicate_groups(k) != [list(range(B_ROUNDS))], k
slow_lost_old = sum(so["window_overflow_by_tenant"][1:])
slow_lost_quo = sum(sq["window_overflow_by_tenant"][1:])
assert slow_lost_old > 0 and slow_lost_quo == 0
print(f"✓ bursty demo: oldest-first evicted {slow_lost_old} live slow-tenant "
      f"docs (groups broken, e.g. tenant 1 → {svc_old.duplicate_groups(1)}); "
      f"quota evicted {slow_lost_quo} (chains intact, "
      f"{sq['window_overflow_by_tenant'][0]} self-evictions stay the bursty "
      f"tenant's own problem)")
