"""End-to-end driver: one engine serving many logical streams.

Eight tenants — each with its own similarity threshold and decay horizon —
submit tiny per-request batches that no single tenant could fill a
micro-batch with.  The multi-tenant runtime coalesces them onto one
stream-tagged device engine (DESIGN.md §9): cross-tenant pairs are masked
on device, per-tenant (θ, λ) rides a small device table, and the service
groups near-duplicates under namespaced (tenant, uid) keys.

The same traffic then replays on the **sharded** variant (DESIGN.md §10):
the identical service facade over ``ShardedFacade`` spreads the ring
window across P in-process shards (host-platform device-count trick) and
must produce the identical per-tenant groups.

    PYTHONPATH=src python examples/multi_tenant_service.py
"""

import os

N_SHARDS = 2
# the device-count trick must land before jax initializes (first repro import)
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402

from repro.runtime import TenantTable  # noqa: E402
from repro.serving import MultiTenantSSSJService  # noqa: E402

rng = np.random.default_rng(0)
K, DIM, ROUNDS, PER_SUBMIT = 8, 64, 12, 3

# strict tenants (high θ, short horizon) next to permissive ones
table = TenantTable(
    thetas=[0.95, 0.9, 0.85, 0.9, 0.95, 0.8, 0.9, 0.85],
    lams=[0.2, 0.05, 0.1, 0.02, 0.5, 0.05, 0.1, 0.2],
)

# every tenant periodically re-posts a noisy copy of its own base document
bases = rng.standard_normal((K, DIM)).astype(np.float32)
traffic = []                       # (tenant, docs, timestamps), replayable
t = 0.0
for r in range(ROUNDS):
    for k in range(K):
        docs = rng.standard_normal((PER_SUBMIT, DIM)).astype(np.float32)
        docs[0] = bases[k] + 0.01 * rng.standard_normal(DIM)
        traffic.append((k, docs, t + np.arange(PER_SUBMIT) * 1e-3))
        t += 0.01


def drive(svc):
    per_round = 0
    for k, docs, ts in traffic:
        svc.submit(k, docs, ts)
        per_round += 1
        if per_round % K == 0:
            svc.flush(final=False)  # coalesce: full micro-batches only
    svc.flush(final=True)
    return svc


svc = drive(MultiTenantSSSJService(table, dim=DIM, capacity=1024, micro_batch=32))
stats = svc.stats()
assert stats["n_items"] == K * ROUNDS * PER_SUBMIT
assert stats["pairs_dropped"] == 0
for k in range(K):
    groups = svc.duplicate_groups(k)
    # each tenant's planted repost chain groups under its OWN local uids;
    # nothing leaked across streams
    assert groups and max(len(g) for g in groups) >= ROUNDS // 2, (k, groups)
print(f"✓ {K} tenants, {stats['n_items']} documents on one engine; "
      f"padding waste {stats['padding_waste']:.1%}, "
      f"{stats['spans_dispatched']} device dispatches, "
      f"per-tenant groups e.g. tenant 0 → {svc.duplicate_groups(0)[:1]}")

# ---- sharded variant: same service, ring window over N_SHARDS shards ---- #
import jax  # noqa: E402

mesh = jax.make_mesh((N_SHARDS,), ("data",))
svc_sh = drive(MultiTenantSSSJService(
    table, dim=DIM, capacity=1024, micro_batch=32, mesh=mesh,
))
sh = svc_sh.stats()
assert sh["pairs_dropped"] == 0 and sh["n_shards"] == N_SHARDS
for k in range(K):
    assert svc_sh.duplicate_groups(k) == svc.duplicate_groups(k), k
print(f"✓ sharded: identical per-tenant groups over {N_SHARDS} shards "
      f"(per-shard live slots {sh['shards']['live_slots']}, "
      f"per-shard pairs {sh['shards']['pairs_emitted']})")
