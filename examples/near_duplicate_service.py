"""End-to-end driver: near-duplicate filtering service (paper app #2).

Serves a small LM with batched requests: token sequences arrive in request
batches, are embedded by the qwen3-family backbone, and flow through the
streaming similarity self-join; duplicate groups are reported online.

    PYTHONPATH=src python examples/near_duplicate_service.py
"""

from repro.launch.serve import run_service

service, groups, trends = run_service(
    "qwen3-0.6b",
    requests=24,
    batch=16,
    seq=64,
    theta=0.9,
    lam=0.05,
    dup_frac=0.3,
)

assert service.stats.n_items == 24 * 16
assert groups, "expected the planted near-duplicates to form groups"
# the join runs on the device-resident engine: compacted emission only
assert service.stats.pairs_dropped == 0
assert service.stats.bytes_to_host < service.engine.bytes_dense_equiv
print(f"\n✓ service processed {service.stats.n_items} documents, "
      f"found {len(groups)} duplicate groups "
      f"(largest: {max(len(g) for g in groups)}); "
      f"{service.stats.bytes_to_host} B drained "
      f"(dense path would have moved {service.engine.bytes_dense_equiv} B)")
