"""Quickstart: the streaming similarity self-join in 60 lines.

Runs the same stream through (a) the paper-faithful STR-L2 joiner, (b) the
TPU-native blocked engine, and (c) the brute-force oracle — and shows they
agree.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    Counters, StreamingJoiner, brute_force_join, join_stream, make_index,
    time_horizon,
)
from repro.core.blocked import BlockedJoinConfig, BlockedStreamJoiner
from repro.core.types import StreamItem, sparse_from_dense
from repro.data.synth import dense_embedding_stream

THETA, LAM = 0.85, 0.1

# a stream of 400 unit vectors with planted near-duplicates
vecs, ts = dense_embedding_stream(400, 64, seed=0, rate=1.0, dup_frac=0.2,
                                  signed=False)
print(f"θ={THETA}  λ={LAM}  ⇒ time horizon τ={time_horizon(THETA, LAM):.2f}")

# (a) paper-faithful: STR framework + L2 index (the paper's winner)
items = [StreamItem(i, float(ts[i]), sparse_from_dense(vecs[i]))
         for i in range(len(vecs))]
counters = Counters()
joiner = StreamingJoiner(make_index("L2", THETA, LAM, streaming=True),
                         counters=counters)
pairs_str = {p.key() for p in join_stream(joiner, items)}
print(f"STR-L2: {len(pairs_str)} similar pairs; "
      f"{counters.entries_traversed} posting entries traversed, "
      f"{counters.entries_pruned} pruned by time filtering")

# (b) TPU-native blocked engine (Pallas kernel in interpret mode on CPU)
cfg = BlockedJoinConfig(theta=THETA, lam=LAM, capacity=512, d=64,
                        block_q=64, block_w=64, chunk_d=32)
engine = BlockedStreamJoiner(cfg)
pairs_tpu = set()
for i in range(0, len(vecs), 64):
    for a, b, score in engine.push(vecs[i:i + 64], ts[i:i + 64]):
        pairs_tpu.add((min(a, b), max(a, b)))
print(f"blocked engine: {len(pairs_tpu)} pairs; "
      f"{engine.chunks_executed}/{engine.tiles_total * (64 // 32)} "
      f"d-chunks executed (tile pruning)")

# (c) ground truth
truth = {p.key() for p in brute_force_join(items, THETA, LAM)}
assert pairs_str == truth, "faithful core diverged from oracle!"
assert pairs_tpu == truth, "blocked engine diverged from oracle!"
print(f"all three agree on {len(truth)} pairs ✓")
