"""Training driver with the SSSJ streaming-dedup data pipeline.

Trains a reduced qwen3-family model while the data pipeline drops
near-duplicate documents within the time horizon before batching (paper
application #2 as a data-quality stage), and checkpoints atomically.

    PYTHONPATH=src python examples/train_with_dedup.py
"""

import tempfile

from repro.launch.train import run_training

ckpt = tempfile.mkdtemp(prefix="sssj_ckpt_")
params, history = run_training(
    "qwen3-0.6b",
    smoke=True,
    steps=30,
    batch=8,
    seq=64,
    ckpt_dir=ckpt,
    ckpt_every=10,
    dedup=True,          # ← the paper's technique in the data pipeline
    peak_lr=3e-3,
    log_every=5,
)

assert history[-1] < history[0], "loss did not decrease"
print(f"\n✓ trained 30 steps with streaming dedup; "
      f"loss {history[0]:.3f} → {history[-1]:.3f}; checkpoints in {ckpt}")
