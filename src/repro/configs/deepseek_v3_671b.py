"""deepseek-v3-671b — [moe] 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared+256 routed top-8, MTP
[arXiv:2412.19437; hf].

``d_ff = 2048`` is the per-expert width; the 3 leading dense layers use
``d_ff_dense = 18432`` (the published dense-MLP width).  Attention is MLA
(latent KV cache), router is sigmoid-scoring top-8 with 1 shared expert,
and the MTP (multi-token-prediction) head adds one extra dense block.
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129_280,
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        n_dense_layers=3,
        d_ff_dense=18_432,
        router_type="sigmoid",
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    mtp=True,
    notes="MLA + sigmoid-routed 256e top-8 MoE + shared expert + MTP",
)
