"""Architecture registry: the 10 assigned configs + shape cells.

``get_config(arch_id)`` returns the full-size :class:`ModelConfig`;
``get_config(arch_id).reduced()`` the CPU-smoke variant.  ``cells()``
enumerates the (arch × shape) dry-run grid, applying the assignment's skip
rules (``long_500k`` only for sub-quadratic families).
"""

from __future__ import annotations

from typing import Dict, Iterator, Tuple

from .base import (  # noqa: F401
    HybridConfig, MLAConfig, ModelConfig, MoEConfig, SHAPES, ShapeConfig,
    SSMConfig, XLSTMConfig,
)
from .qwen3_0_6b import CONFIG as _qwen3_0_6b
from .deepseek_coder_33b import CONFIG as _deepseek_coder_33b
from .qwen2_5_3b import CONFIG as _qwen2_5_3b
from .codeqwen1_5_7b import CONFIG as _codeqwen1_5_7b
from .chameleon_34b import CONFIG as _chameleon_34b
from .zamba2_2_7b import CONFIG as _zamba2_2_7b
from .musicgen_medium import CONFIG as _musicgen_medium
from .xlstm_350m import CONFIG as _xlstm_350m
from .deepseek_v3_671b import CONFIG as _deepseek_v3_671b
from .olmoe_1b_7b import CONFIG as _olmoe_1b_7b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _qwen3_0_6b,
        _deepseek_coder_33b,
        _qwen2_5_3b,
        _codeqwen1_5_7b,
        _chameleon_34b,
        _zamba2_2_7b,
        _musicgen_medium,
        _xlstm_350m,
        _deepseek_v3_671b,
        _olmoe_1b_7b,
    )
}

__all__ = [
    "ARCHS", "SHAPES", "get_config", "cells", "cell_enabled",
    "ModelConfig", "ShapeConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "HybridConfig", "XLSTMConfig",
]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return ARCHS[arch]


def cell_enabled(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Apply the assignment's skip rules.  Returns (enabled, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip per assignment)"
        )
    return True, ""


def cells() -> Iterator[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All 40 (arch × shape) cells with their enabled/skip status."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = cell_enabled(cfg, shape)
            yield cfg, shape, ok, why
