"""Config dataclasses for model architectures and run shapes.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
reduced smoke variants reuse the same dataclass (see ``reduced()``).
Logical-axis names used in sharding specs are documented in
:mod:`repro.distributed.sharding`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "HybridConfig",
    "XLSTMConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    n_dense_layers: int = 0          # leading layers that use a dense MLP
    d_ff_dense: int = 0              # their hidden size (0 ⇒ use d_ff)
    capacity_factor: float = 1.25
    router_type: str = "softmax"     # "softmax" | "sigmoid" (deepseek-v3)
    # GShard-style dispatch groups: queue positions are cumsum'd *within*
    # a group (one per data shard) with per-group capacity, so the dispatch
    # needs no global sequential cumsum (perf iteration M2).  Must divide
    # the per-step token count; falls back to 1 group otherwise.
    dispatch_groups: int = 16


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone + one *shared* attention+MLP block
    invoked every ``shared_every`` layers (weights reused per invocation)."""

    shared_every: int = 6


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: mLSTM blocks with an sLSTM block every ``slstm_every`` (7:1)."""

    slstm_every: int = 8
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    input_kind: str = "tokens"       # tokens | embeddings (stub frontends)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    mtp: bool = False                # DeepSeek-V3 multi-token prediction head
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def block_kind(self) -> str:
        if self.xlstm is not None:
            return "xlstm"
        if self.hybrid is not None:
            return "hybrid"
        if self.ssm is not None:
            return "ssm"
        return "transformer"

    def reduced(self, **over) -> "ModelConfig":
        """A small same-family variant for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            head_dim=16,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            tie_embeddings=self.tie_embeddings,
            input_kind=self.input_kind,
            mtp=self.mtp,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=8,
                top_k=2,
                d_ff_expert=32,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                n_dense_layers=min(self.moe.n_dense_layers, 1),
                d_ff_dense=64 if self.moe.n_dense_layers else 0,
            )
        if self.mla:
            kw["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16,
            )
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32
            )
        if self.hybrid:
            kw["hybrid"] = HybridConfig(shared_every=2)
        if self.xlstm:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2)
        kw.update(over)
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
