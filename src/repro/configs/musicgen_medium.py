"""musicgen-medium — [audio] 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens  [arXiv:2306.05284; hf].

The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed
audio-frame embeddings (B, S, d_model); the backbone decodes over the
2048-entry codebook vocabulary.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    input_kind="embeddings",
    notes="decoder-only over EnCodec codebook tokens; frontend stubbed",
)
