"""xlstm-350m — [ssm] 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks  [arXiv:2405.04517; unverified].

``d_ff = 0``: all FFN capacity lives inside the m/sLSTM blocks (mLSTM
pre-up-projection factor 2, sLSTM post-up GeGLU factor 4/3).
Sub-quadratic (recurrent state) ⇒ runs long_500k.
"""

from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    xlstm=XLSTMConfig(
        slstm_every=8, mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
        conv_width=4,
    ),
    notes="7:1 mLSTM:sLSTM blocks (sLSTM at positions 7, 15, 23)",
)
