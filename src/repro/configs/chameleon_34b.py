"""chameleon-34b — [vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens  [arXiv:2405.09818; unverified].

The modality frontend (VQ-VAE image tokenizer) is a STUB: ``input_specs()``
provides precomputed patch/VQ-token *embeddings* (B, S, d_model); the
backbone is the early-fusion decoder over the shared 65536 vocab.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,            # chameleon adds qk-norm for training stability
    input_kind="embeddings",
    notes="early-fusion VLM backbone; frontend stubbed to embeddings",
)
