"""zamba2-2.7b — [hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]."""

from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    hybrid=HybridConfig(shared_every=6),
    notes="Mamba2 backbone; one shared attention+MLP block every 6 layers "
          "(weights reused).  Sub-quadratic ⇒ runs long_500k.",
)
