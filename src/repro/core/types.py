"""Core data types for the streaming similarity self-join (SSSJ).

The paper operates on unit-normalized sparse vectors arriving on a
timestamped stream.  This module defines the faithful (CPU-side)
representations used by the reference implementation of the paper's
algorithms; the TPU-native engine (``repro.core.blocked`` and
``repro.kernels.sssj_join``) uses dense ``(n, d)`` tiles instead.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

import numpy as np

__all__ = [
    "SparseVector",
    "StreamItem",
    "Pair",
    "make_sparse",
    "sparse_from_dense",
    "sparse_to_dense",
    "sparse_dot",
    "unit_normalize",
]


@dataclasses.dataclass(frozen=True)
class SparseVector:
    """A sparse vector with coordinates sorted by dimension index.

    Attributes:
      indices: int32 array of dimension ids, strictly increasing.
      values:  float64 array of the same length, all non-zero.
    """

    indices: np.ndarray
    values: np.ndarray

    def __post_init__(self) -> None:
        if self.indices.shape != self.values.shape:
            raise ValueError("indices/values shape mismatch")

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def max_value(self) -> float:
        """``vm_x`` in the paper: the maximum coordinate value."""
        return float(self.values.max()) if self.nnz else 0.0

    @property
    def coord_sum(self) -> float:
        """``Σ_x`` in the paper: the sum of coordinate values."""
        return float(self.values.sum())

    @property
    def norm(self) -> float:
        return float(np.sqrt(np.sum(self.values * self.values)))

    def prefix(self, k: int) -> "SparseVector":
        """The prefix ``x'`` consisting of the first ``k`` stored coords."""
        return SparseVector(self.indices[:k], self.values[:k])

    def suffix(self, k: int) -> "SparseVector":
        return SparseVector(self.indices[k:], self.values[k:])


@dataclasses.dataclass(frozen=True)
class StreamItem:
    """A timestamped vector on the input stream."""

    uid: int
    t: float
    vec: SparseVector


@dataclasses.dataclass(frozen=True)
class Pair:
    """An emitted similar pair.

    ``sim`` is the *raw* cosine similarity ``dot(x, y)``; ``decayed`` is the
    time-dependent similarity ``sim * exp(-lambda * |t(x) - t(y)|)`` that the
    SSSJ problem thresholds on.
    """

    uid_a: int
    uid_b: int
    sim: float
    decayed: float

    def key(self) -> tuple[int, int]:
        a, b = self.uid_a, self.uid_b
        return (a, b) if a < b else (b, a)


def make_sparse(indices: Sequence[int], values: Sequence[float]) -> SparseVector:
    idx = np.asarray(indices, dtype=np.int32)
    val = np.asarray(values, dtype=np.float64)
    order = np.argsort(idx, kind="stable")
    idx, val = idx[order], val[order]
    keep = val != 0.0
    return SparseVector(idx[keep], val[keep])


def sparse_from_dense(x: np.ndarray) -> SparseVector:
    idx = np.nonzero(x)[0].astype(np.int32)
    return SparseVector(idx, x[idx].astype(np.float64))


def sparse_to_dense(x: SparseVector, dim: int) -> np.ndarray:
    out = np.zeros(dim, dtype=np.float64)
    out[x.indices] = x.values
    return out


def sparse_dot(x: SparseVector, y: SparseVector) -> float:
    """Dot product of two sorted sparse vectors (merge join)."""
    inter, ix, iy = np.intersect1d(
        x.indices, y.indices, assume_unique=True, return_indices=True
    )
    if inter.size == 0:
        return 0.0
    return float(np.dot(x.values[ix], y.values[iy]))


def unit_normalize(x: SparseVector) -> SparseVector:
    n = x.norm
    if n == 0.0:
        return x
    return SparseVector(x.indices, x.values / n)


def as_stream(
    vectors: Iterable[SparseVector], timestamps: Iterable[float]
) -> Iterator[StreamItem]:
    """Zip vectors with non-decreasing timestamps into stream items."""
    last = -np.inf
    for uid, (vec, t) in enumerate(zip(vectors, timestamps)):
        if t < last:
            raise ValueError(f"timestamps must be non-decreasing: {t} < {last}")
        last = t
        yield StreamItem(uid=uid, t=float(t), vec=unit_normalize(vec))
