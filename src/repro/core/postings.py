"""Posting lists and score accumulators for the index schemes.

The paper (§6.2) implements posting lists as circular byte buffers that
double when full and halve when 3/4 empty, so that time-filter truncation
from the head is O(1).  We mirror that with growable NumPy arrays plus a
``head`` offset: truncation advances ``head``; compaction (copy-down)
happens only when the dead prefix exceeds half the capacity — amortized
O(1) per appended entry.

Each posting entry for dimension ``j`` is the paper's triple
``(ι(x), x_j, ||x'_j||)`` plus the arrival timestamp ``t(x)`` needed by the
streaming variants.
"""

from __future__ import annotations

import numpy as np

__all__ = ["PostingList", "ScoreAccumulator", "ItemMeta"]

_INIT_CAP = 8


class PostingList:
    """A single inverted-index list ``I_j`` with O(1) head truncation."""

    __slots__ = ("ids", "vals", "pnorms", "ts", "head", "size")

    def __init__(self) -> None:
        self.ids = np.empty(_INIT_CAP, dtype=np.int64)
        self.vals = np.empty(_INIT_CAP, dtype=np.float64)
        self.pnorms = np.empty(_INIT_CAP, dtype=np.float64)
        self.ts = np.empty(_INIT_CAP, dtype=np.float64)
        self.head = 0
        self.size = 0  # logical end (exclusive); active region is [head, size)

    def __len__(self) -> int:
        return self.size - self.head

    def _grow(self) -> None:
        cap = self.ids.shape[0] * 2
        for name in ("ids", "vals", "pnorms", "ts"):
            old = getattr(self, name)
            new = np.empty(cap, dtype=old.dtype)
            new[: self.size] = old[: self.size]
            setattr(self, name, new)

    def _compact(self) -> None:
        n = len(self)
        for name in ("ids", "vals", "pnorms", "ts"):
            arr = getattr(self, name)
            arr[:n] = arr[self.head : self.size]
        self.head, self.size = 0, n

    def append(self, uid: int, val: float, pnorm: float, t: float) -> None:
        if self.size == self.ids.shape[0]:
            if self.head > self.ids.shape[0] // 2:
                self._compact()
            else:
                self._grow()
        i = self.size
        self.ids[i] = uid
        self.vals[i] = val
        self.pnorms[i] = pnorm
        self.ts[i] = t
        self.size += 1

    def active(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        s = slice(self.head, self.size)
        return self.ids[s], self.vals[s], self.pnorms[s], self.ts[s]

    def truncate_before_time(self, t_min: float) -> int:
        """Drop entries with ``t < t_min`` **assuming time-sorted entries**.

        This is the INV/L2 fast path (paper §6.2, "backwards scanning"):
        because entries are appended in arrival order, a binary search finds
        the first live entry and the whole expired prefix is dropped in O(1)
        (head advance).  Returns the number of entries pruned.
        """
        lo, hi = self.head, self.size
        cut = int(np.searchsorted(self.ts[lo:hi], t_min, side="left")) + lo
        pruned = cut - self.head
        self.head = cut
        if self.head == self.size:
            self.head = self.size = 0
        return pruned

    def filter_expired_unordered(self, t_min: float) -> int:
        """Drop entries with ``t < t_min`` when the list is NOT time-sorted.

        This is the L2AP path: re-indexing appends out-of-order entries, so
        the list must be scanned fully and compacted (paper §6.2 notes this
        as the reason L2AP loses its time-filtering fast path).
        Returns the number of entries pruned.
        """
        lo, hi = self.head, self.size
        keep = self.ts[lo:hi] >= t_min
        n_keep = int(keep.sum())
        pruned = (hi - lo) - n_keep
        if pruned:
            for name in ("ids", "vals", "pnorms", "ts"):
                arr = getattr(self, name)
                arr[lo : lo + n_keep] = arr[lo:hi][keep]
            self.size = lo + n_keep
            if self.head == self.size:
                self.head = self.size = 0
        return pruned


class ItemMeta:
    """Per-item metadata arrays keyed by ``uid - base`` (uids are monotone).

    Stores what CG/CV need about *indexed* items: arrival time, nnz and max
    value of the full vector (AP size bound, line 8 of Alg. 3).
    """

    __slots__ = ("base", "t", "nnz", "vm", "n")

    def __init__(self, cap: int = 64) -> None:
        self.base = 0
        self.n = 0
        self.t = np.zeros(cap, dtype=np.float64)
        self.nnz = np.zeros(cap, dtype=np.int64)
        self.vm = np.zeros(cap, dtype=np.float64)

    def add(self, uid: int, t: float, nnz: int, vm: float) -> None:
        if self.n == 0:
            self.base = uid
        i = uid - self.base
        cap = self.t.shape[0]
        if i >= cap:
            new_cap = max(cap * 2, i + 1)
            for name in ("t", "nnz", "vm"):
                old = getattr(self, name)
                new = np.zeros(new_cap, dtype=old.dtype)
                new[: self.n] = old[: self.n]
                setattr(self, name, new)
        self.t[i] = t
        self.nnz[i] = nnz
        self.vm[i] = vm
        self.n = max(self.n, i + 1)

    def rebase(self, new_base: int) -> None:
        """Forget everything before ``new_base`` (time-filter eviction)."""
        if new_base <= self.base:
            return
        off = new_base - self.base
        if off >= self.n:
            self.base, self.n = new_base, 0
            return
        for name in ("t", "nnz", "vm"):
            arr = getattr(self, name)
            arr[: self.n - off] = arr[off : self.n]
        self.base = new_base
        self.n -= off

    def lookup(self, uids: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        idx = uids - self.base
        return self.t[idx], self.nnz[idx], self.vm[idx]


class ScoreAccumulator:
    """The candidate score array ``C`` of Algorithms 3/7.

    Dense arrays indexed by ``uid - base`` (cheap because the time filter
    keeps the live uid range narrow).  ``touched`` tracks which uids have a
    non-zero accumulated score so CV can iterate only over candidates.
    ``killed`` marks candidates pruned by the l2bound (Alg. 3 line 13 sets
    ``C[ι(y)] ← 0``; we keep an explicit flag so a killed candidate is never
    re-admitted, which matches the semantics while avoiding wasted work —
    the paper's version remains correct because such candidates can never
    pass verification, see DESIGN.md §8).
    """

    __slots__ = ("base", "score", "killed", "touched")

    def __init__(self, base: int, span: int) -> None:
        self.base = base
        self.score = np.zeros(max(span, 1), dtype=np.float64)
        self.killed = np.zeros(max(span, 1), dtype=bool)
        self.touched: list[np.ndarray] = []

    def candidates(self) -> np.ndarray:
        """Distinct uids with positive accumulated score, ascending."""
        if not self.touched:
            return np.empty(0, dtype=np.int64)
        uids = np.unique(np.concatenate(self.touched))
        idx = uids - self.base
        live = (self.score[idx] > 0.0) & ~self.killed[idx]
        return uids[live]

    def get(self, uids: np.ndarray) -> np.ndarray:
        return self.score[uids - self.base]
