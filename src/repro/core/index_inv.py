"""INV: plain inverted index (paper §5.1), static and streaming variants.

The inverted index stores *every* non-zero coordinate.  Candidate
generation accumulates the exact dot product, so verification is a pure
threshold test.  The streaming variant keeps posting lists time-ordered
and uses the O(1) truncate-on-first-expired fast path (paper §6.2).
"""

from __future__ import annotations

import math
from collections import deque
from typing import List, Optional

import numpy as np

from .counters import Counters
from .postings import ItemMeta, PostingList, ScoreAccumulator
from .similarity import time_horizon
from .types import Pair, StreamItem

__all__ = ["InvIndex"]


class InvIndex:
    """Plain inverted index, no index-pruning bounds."""

    name = "INV"

    def __init__(
        self,
        theta: float,
        lam: float = 0.0,
        *,
        streaming: bool = False,
        counters: Optional[Counters] = None,
    ) -> None:
        self.theta = theta
        self.lam = lam
        self.streaming = streaming
        self.tau = time_horizon(theta, lam) if streaming else math.inf
        self.lists: dict[int, PostingList] = {}
        self.meta = ItemMeta()
        self.counters = counters if counters is not None else Counters()
        self._arrivals: deque[tuple[int, float]] = deque()
        self._floor_uid = 0  # smallest possibly-alive uid
        self._next_uid_hint = 0
        self._n_entries = 0

    # ------------------------------------------------------------------ #
    # shared internals
    # ------------------------------------------------------------------ #
    def _add_to_index(self, item: StreamItem) -> None:
        vec = item.vec
        self.meta.add(item.uid, item.t, vec.nnz, vec.max_value)
        for j, v in zip(vec.indices.tolist(), vec.values.tolist()):
            self.lists.setdefault(j, PostingList()).append(item.uid, v, 0.0, item.t)
        self._n_entries += len(vec.indices)
        self.counters.entries_indexed += vec.nnz
        self.counters.peak_index_entries = max(
            self.counters.peak_index_entries, self._n_entries
        )
        self._next_uid_hint = max(self._next_uid_hint, item.uid + 1)
        if self.streaming:
            self._arrivals.append((item.uid, item.t))

    def _evict(self, now: float) -> None:
        t_min = now - self.tau
        while self._arrivals and self._arrivals[0][1] < t_min:
            uid, _ = self._arrivals.popleft()
            self._floor_uid = uid + 1
        self.meta.rebase(self._floor_uid)

    def _cand_gen(self, item: StreamItem) -> ScoreAccumulator:
        span = self._next_uid_hint - self._floor_uid + 1
        acc = ScoreAccumulator(self._floor_uid, span)
        t_min = item.t - self.tau
        for j, xj in zip(item.vec.indices.tolist(), item.vec.values.tolist()):
            pl = self.lists.get(j)
            if pl is None or len(pl) == 0:
                continue
            if self.streaming:
                pruned = pl.truncate_before_time(t_min)
                self.counters.entries_pruned += pruned
                self._n_entries -= pruned
            ids, vals, _, _ = pl.active()
            if ids.size == 0:
                continue
            self.counters.entries_traversed += int(ids.size)
            np.add.at(acc.score, ids - acc.base, xj * vals)
            acc.touched.append(ids)
        return acc

    def _cand_ver(self, item: StreamItem, acc: ScoreAccumulator, decayed: bool) -> List[Pair]:
        cands = acc.candidates()
        self.counters.candidates_generated += int(cands.size)
        if cands.size == 0:
            return []
        scores = acc.get(cands)
        if decayed:
            t_y, _, _ = self.meta.lookup(cands)
            dec = np.exp(-self.lam * np.abs(item.t - t_y))
            final = scores * dec
        else:
            final = scores
        keep = final >= self.theta
        out = [
            Pair(uid_a=item.uid, uid_b=int(u), sim=float(s), decayed=float(f))
            for u, s, f in zip(cands[keep], scores[keep], final[keep])
        ]
        self.counters.pairs_emitted += len(out)
        return out

    # ------------------------------------------------------------------ #
    # static (MiniBatch) API
    # ------------------------------------------------------------------ #
    def construct(
        self, items: List[StreamItem], m_global: Optional[dict] = None
    ) -> List[Pair]:
        """IndConstr-INV: build the index over ``items``, reporting all
        raw-similar pairs among them (Alg. 1 line 14)."""
        del m_global  # INV needs no dataset statistics
        out: List[Pair] = []
        for item in items:
            acc = self._cand_gen(item)
            out.extend(self._cand_ver(item, acc, decayed=False))
            self._add_to_index(item)
            self.counters.items_processed += 1
        return out

    def query(self, item: StreamItem) -> List[Pair]:
        """CandGen+CandVer against the built index (raw similarity)."""
        acc = self._cand_gen(item)
        self.counters.items_processed += 1
        return self._cand_ver(item, acc, decayed=False)

    # ------------------------------------------------------------------ #
    # streaming (STR) API
    # ------------------------------------------------------------------ #
    def process(self, item: StreamItem) -> List[Pair]:
        """STR-INV: query with time filtering, then index (Alg. 5)."""
        assert self.streaming, "process() requires streaming=True"
        self._evict(item.t)
        acc = self._cand_gen(item)
        pairs = self._cand_ver(item, acc, decayed=True)
        self._add_to_index(item)
        self.counters.items_processed += 1
        return pairs
