"""repro.core — the paper's contribution: streaming similarity self-join.

Public surface:

  * :func:`make_joiner` — build any (framework × index) combination from the
    paper: frameworks ``{"MB", "STR"}`` × indexes ``{"INV", "AP", "L2AP", "L2"}``
    (STR-AP is excluded, as in the paper).
  * :func:`join_stream` — run a joiner over an iterable of stream items.
  * The faithful building blocks (:class:`InvIndex`, :class:`L2FamilyIndex`,
    :class:`MiniBatchJoiner`, :class:`StreamingJoiner`) and the oracle
    (:func:`brute_force_join`).
  * The TPU-native engine lives in :mod:`repro.core.blocked` and
    :mod:`repro.core.distributed`.
"""

from __future__ import annotations

from typing import Iterable, List, Optional

from .counters import Counters
from .index_inv import InvIndex
from .index_l2 import L2FamilyIndex
from .minibatch import MiniBatchJoiner, apply_decay
from .similarity import (
    brute_force_join,
    decay_lambda_for,
    decayed_similarity,
    time_horizon,
)
from .streaming import StreamingJoiner
from .types import (
    Pair,
    SparseVector,
    StreamItem,
    as_stream,
    make_sparse,
    sparse_dot,
    sparse_from_dense,
    unit_normalize,
)

__all__ = [
    "Counters",
    "InvIndex",
    "L2FamilyIndex",
    "MiniBatchJoiner",
    "StreamingJoiner",
    "Pair",
    "SparseVector",
    "StreamItem",
    "as_stream",
    "make_sparse",
    "sparse_dot",
    "sparse_from_dense",
    "unit_normalize",
    "apply_decay",
    "brute_force_join",
    "decayed_similarity",
    "decay_lambda_for",
    "time_horizon",
    "make_index",
    "make_joiner",
    "join_stream",
    "INDEX_NAMES",
    "FRAMEWORK_NAMES",
]

INDEX_NAMES = ("INV", "AP", "L2AP", "L2")
FRAMEWORK_NAMES = ("MB", "STR")


def make_index(
    name: str,
    theta: float,
    lam: float = 0.0,
    *,
    streaming: bool = False,
    counters: Optional[Counters] = None,
):
    name = name.upper()
    if name == "INV":
        return InvIndex(theta, lam, streaming=streaming, counters=counters)
    flags = {"AP": (True, False), "L2AP": (True, True), "L2": (False, True)}
    if name not in flags:
        raise ValueError(f"unknown index {name!r}; choose from {INDEX_NAMES}")
    use_ap, use_l2 = flags[name]
    return L2FamilyIndex(
        theta, lam, use_ap=use_ap, use_l2=use_l2, streaming=streaming, counters=counters
    )


def make_joiner(
    framework: str,
    index: str,
    theta: float,
    lam: float,
    counters: Optional[Counters] = None,
):
    """Build e.g. ``make_joiner("STR", "L2", theta=0.9, lam=0.01)``."""
    framework = framework.upper()
    if framework == "MB":
        return MiniBatchJoiner(
            lambda: make_index(index, theta, 0.0, streaming=False),
            theta,
            lam,
            counters=counters,
        )
    if framework == "STR":
        idx = make_index(index, theta, lam, streaming=True)
        return StreamingJoiner(idx, counters=counters)
    raise ValueError(f"unknown framework {framework!r}; choose from {FRAMEWORK_NAMES}")


def join_stream(joiner, items: Iterable[StreamItem]) -> List[Pair]:
    out: List[Pair] = []
    for item in items:
        out.extend(joiner.push(item))
    out.extend(joiner.finish())
    return out
