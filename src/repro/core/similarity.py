"""Time-dependent similarity and the time-filtering horizon (paper §3)."""

from __future__ import annotations

import math
from typing import Iterable, List

import numpy as np

from .types import Pair, SparseVector, StreamItem, sparse_dot

__all__ = [
    "decayed_similarity",
    "time_horizon",
    "decay_lambda_for",
    "brute_force_join",
]


def decayed_similarity(sim: float, dt: float, lam: float) -> float:
    """``sim_Δt(x, y) = dot(x, y) * exp(-λ |t(x) - t(y)|)``."""
    return sim * math.exp(-lam * abs(dt))


def time_horizon(theta: float, lam: float) -> float:
    """``τ = λ⁻¹ log θ⁻¹`` — pairs further apart in time cannot be similar.

    Follows from ``dot(x, y) ≤ 1`` for unit vectors:
    ``sim_Δt ≤ exp(-λ Δt) < θ  ⟺  Δt > λ⁻¹ log θ⁻¹``.
    """
    if not 0.0 < theta <= 1.0:
        raise ValueError(f"theta must be in (0, 1], got {theta}")
    if lam < 0.0:
        raise ValueError(f"lambda must be >= 0, got {lam}")
    if lam == 0.0:
        return math.inf
    return math.log(1.0 / theta) / lam


def decay_lambda_for(theta: float, tau: float) -> float:
    """Parameter-setting recipe from paper §3: ``λ = τ⁻¹ log θ⁻¹``."""
    return math.log(1.0 / theta) / tau


def brute_force_join(
    items: Iterable[StreamItem], theta: float, lam: float
) -> List[Pair]:
    """O(n²) ground-truth oracle for the SSSJ problem (testing only)."""
    buf = list(items)
    out: List[Pair] = []
    for i in range(len(buf)):
        for j in range(i):
            x, y = buf[i], buf[j]
            s = sparse_dot(x.vec, y.vec)
            d = decayed_similarity(s, x.t - y.t, lam)
            if d >= theta:
                out.append(Pair(uid_a=x.uid, uid_b=y.uid, sim=s, decayed=d))
    return out


def brute_force_join_dense(
    mat: np.ndarray, ts: np.ndarray, theta: float, lam: float
) -> List[Pair]:
    """Dense-matrix oracle: rows of ``mat`` are unit vectors."""
    sims = mat @ mat.T
    dts = np.abs(ts[:, None] - ts[None, :])
    dec = sims * np.exp(-lam * dts)
    out: List[Pair] = []
    n = mat.shape[0]
    for i in range(n):
        for j in range(i):
            if dec[i, j] >= theta:
                out.append(
                    Pair(uid_a=i, uid_b=j, sim=float(sims[i, j]), decayed=float(dec[i, j]))
                )
    return out
