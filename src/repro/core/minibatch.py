"""The MiniBatch (MB) framework — paper Algorithm 1 + §6.1 refinements.

MB slices the stream into windows of length τ and uses a *static* APSS
index as a black box:

  * items are accumulated into the current window W_k;
  * when W_k closes, IndConstr runs over W_{k-1} (reporting all similar
    pairs *within* W_{k-1}) using the max-vector combined over W_{k-1} ∪ W_k
    (§6.1 — so the AP b1 invariant also covers the upcoming queries), then
    every item of W_k queries that index (reporting *cross-window* pairs);
  * W_{k-2}'s index is dropped.

Every pair with Δt ≤ τ lies within one window or across two consecutive
windows, so MB is complete; ApplyDecay (raw-pair filtering by the decayed
threshold) removes the up-to-2τ-apart false positives that MB inherently
generates (the paper's noted inefficiency — deliberately preserved here,
it is what Fig. 2 measures).
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional

from .counters import Counters
from .similarity import time_horizon
from .types import Pair, StreamItem

__all__ = ["MiniBatchJoiner", "apply_decay"]

IndexFactory = Callable[[], object]


def apply_decay(pairs: List[Pair], lam: float, theta: float, t_of: Dict[int, float]) -> List[Pair]:
    """ApplyDecay (Alg. 1 lines 12/15): re-threshold raw pairs by sim_Δt."""
    out: List[Pair] = []
    for p in pairs:
        dt = abs(t_of[p.uid_a] - t_of[p.uid_b])
        dec = p.sim * math.exp(-lam * dt)
        if dec >= theta:
            out.append(Pair(p.uid_a, p.uid_b, p.sim, dec))
    return out


class MiniBatchJoiner:
    """MB-IDX: any static index scheme, pipelined over two τ-windows."""

    def __init__(
        self,
        index_factory: IndexFactory,
        theta: float,
        lam: float,
        counters: Optional[Counters] = None,
    ) -> None:
        self.index_factory = index_factory
        self.theta = theta
        self.lam = lam
        self.tau = time_horizon(theta, lam)
        if not math.isfinite(self.tau):
            raise ValueError("MB requires a finite horizon (lambda > 0, theta < 1)")
        self.counters = counters if counters is not None else Counters()

        self._prev: List[StreamItem] = []
        self._cur: List[StreamItem] = []
        self._m_prev: Dict[int, float] = {}
        self._m_cur: Dict[int, float] = {}
        self._window_end: Optional[float] = None
        self._t_of: Dict[int, float] = {}

    # ------------------------------------------------------------------ #
    def push(self, item: StreamItem) -> List[Pair]:
        """Feed one stream item; returns pairs emitted by any window close."""
        out: List[Pair] = []
        if self._window_end is None:
            self._window_end = item.t + self.tau
        while item.t >= self._window_end:
            out.extend(self._rotate())
            self._window_end += self.tau
        self._cur.append(item)
        self._t_of[item.uid] = item.t
        for j, v in zip(item.vec.indices.tolist(), item.vec.values.tolist()):
            if v > self._m_cur.get(j, 0.0):
                self._m_cur[j] = v
        return out

    def finish(self) -> List[Pair]:
        """Flush: close the partial window, then once more to emit the
        within-pairs of the final window."""
        out = self._rotate()
        out.extend(self._rotate())
        return out

    # ------------------------------------------------------------------ #
    def _rotate(self) -> List[Pair]:
        out: List[Pair] = []
        if self._prev:
            m_comb = dict(self._m_prev)
            for j, v in self._m_cur.items():
                if v > m_comb.get(j, 0.0):
                    m_comb[j] = v
            index = self.index_factory()
            index.counters = self.counters
            self.counters.index_rebuilds += 1
            within = index.construct(self._prev, m_global=m_comb)
            out.extend(apply_decay(within, self.lam, self.theta, self._t_of))
            for item in self._cur:
                cross = index.query(item)
                out.extend(apply_decay(cross, self.lam, self.theta, self._t_of))
        elif self._cur:
            # very first window has no predecessor; its within-pairs are
            # reported when it becomes the "previous" window below
            pass
        # forget everything older than the previous window
        for it in self._prev:
            self._t_of.pop(it.uid, None)
        self._prev, self._cur = self._cur, []
        self._m_prev, self._m_cur = self._m_cur, {}
        return out
