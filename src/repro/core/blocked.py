"""TPU-native blocked SSSJ engine: ring-buffer window + kernel join.

This is the production (dense) counterpart of the faithful STR-L2
implementation.  The time-filtered index becomes a fixed-capacity ring
buffer of the most recent vectors (the paper's circular-buffer posting
lists, §6.2, turned into a device array); candidate generation + pruning
happen inside the Pallas kernel (:mod:`repro.kernels.sssj_join`), which
applies time filtering and the ℓ2 suffix bound at tile granularity.

Semantics match the faithful core: for each incoming batch the engine
reports (a) pairs between batch items and strictly-earlier window items and
(b) pairs within the batch (uid-ordered), all thresholded on the decayed
similarity.  Eviction is implicit: ring overwrite drops the oldest items,
which the time filter justifies as long as ``capacity ≥ arrival_rate · τ``;
an overflow counter records when live items (still within the horizon) were
overwritten, so operators can size the window.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.sssj_join import sssj_join_scores
from .similarity import time_horizon

__all__ = ["WindowState", "init_window", "BlockedJoinConfig", "BlockedStreamJoiner"]

_EMPTY_T = jnp.float32(3.0e30)


class WindowState(NamedTuple):
    """Sharded ring buffer of recent stream items (a pytree)."""

    vecs: jax.Array    # (capacity, d) f32
    ts: jax.Array      # (capacity,) f32; empty slots hold +3e30
    uids: jax.Array    # (capacity,) i32; empty slots hold -1
    cursor: jax.Array  # () i32 — next write slot
    overflow: jax.Array  # () i32 — live items overwritten (window undersized)


def init_window(capacity: int, d: int, dtype=jnp.float32) -> WindowState:
    return WindowState(
        vecs=jnp.zeros((capacity, d), dtype),
        ts=jnp.full((capacity,), _EMPTY_T, jnp.float32),
        uids=jnp.full((capacity,), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class BlockedJoinConfig:
    theta: float
    lam: float
    capacity: int
    d: int
    block_q: int = 128
    block_w: int = 128
    chunk_d: int = 128
    use_ref: bool = False  # route through the jnp oracle instead of Pallas

    @property
    def tau(self) -> float:
        return time_horizon(self.theta, self.lam)


def push_batch(
    state: WindowState, q: jax.Array, tq: jax.Array, uq: jax.Array
) -> WindowState:
    cap = state.ts.shape[0]
    b = q.shape[0]
    pos = (state.cursor + jnp.arange(b, dtype=jnp.int32)) % cap
    return state._replace(
        vecs=state.vecs.at[pos].set(q.astype(state.vecs.dtype)),
        ts=state.ts.at[pos].set(tq.astype(jnp.float32)),
        uids=state.uids.at[pos].set(uq.astype(jnp.int32)),
        cursor=(state.cursor + b) % cap,
    )


def make_join_step(cfg: BlockedJoinConfig):
    """Build the jitted step:  (state, q, tq, uq) → (state, outputs).

    Outputs:
      ``scores_win``  (B, capacity) — decayed scores vs window (≥ θ else 0)
      ``scores_self`` (B, B)        — decayed scores within the batch
      ``iters_win``   per-tile d-chunk counts (pruning telemetry)
    """

    kw = dict(
        theta=cfg.theta,
        lam=cfg.lam,
        block_q=cfg.block_q,
        block_w=cfg.block_w,
        chunk_d=cfg.chunk_d,
        use_ref=cfg.use_ref,
    )

    def step(state: WindowState, q, tq, uq):
        tq = tq.astype(jnp.float32)
        uq = uq.astype(jnp.int32)
        scores_win, iters_win = sssj_join_scores(
            q, state.vecs, tq, state.ts, uq, state.uids, **kw
        )
        scores_self, _ = sssj_join_scores(q, q, tq, tq, uq, uq, **kw)
        # overflow: live slots (uid >= 0, within horizon of newest arrival)
        # that this push will overwrite
        cap = state.ts.shape[0]
        b = q.shape[0]
        pos = (state.cursor + jnp.arange(b, dtype=jnp.int32)) % cap
        old_t = state.ts[pos]
        old_u = state.uids[pos]
        live = (old_u >= 0) & (tq.max() - old_t <= cfg.tau)
        n_over = jnp.sum(live.astype(jnp.int32))
        new_state = push_batch(state, q, tq, uq)
        new_state = new_state._replace(overflow=state.overflow + n_over)
        return new_state, (scores_win, scores_self, iters_win)

    return jax.jit(step, donate_argnums=(0,))


class BlockedStreamJoiner:
    """Host driver: feeds batches through the jitted join step and extracts
    emitted pairs (uid_a, uid_b, decayed_score) as NumPy arrays."""

    def __init__(self, cfg: BlockedJoinConfig) -> None:
        self.cfg = cfg
        self.state = init_window(cfg.capacity, cfg.d)
        self._step = make_join_step(cfg)
        self._next_uid = 0
        self.chunks_executed = 0
        self.tiles_total = 0

    def push(self, vecs: np.ndarray, ts: np.ndarray):
        b = vecs.shape[0]
        uq = np.arange(self._next_uid, self._next_uid + b, dtype=np.int32)
        # snapshot window uids BEFORE the step (donated buffers)
        w_uids = np.asarray(self.state.uids)
        self._next_uid += b
        self.state, (s_win, s_self, it_win) = self._step(
            self.state, jnp.asarray(vecs), jnp.asarray(ts), jnp.asarray(uq)
        )
        s_win = np.asarray(s_win)
        s_self = np.asarray(s_self)
        it = np.asarray(it_win)
        self.chunks_executed += int(it.sum())
        self.tiles_total += int(it.size)
        pairs = []
        qi, wi = np.nonzero(s_win)
        for a, b_ in zip(qi, wi):
            pairs.append((int(uq[a]), int(w_uids[b_]), float(s_win[a, b_])))
        qi, qj = np.nonzero(s_self)
        for a, b_ in zip(qi, qj):
            pairs.append((int(uq[a]), int(uq[b_]), float(s_self[a, b_])))
        return pairs

    @property
    def overflow(self) -> int:
        return int(np.asarray(self.state.overflow))
