"""Compatibility wrapper over the device-resident engine (repro.engine).

This module used to host the TPU-native blocked join driver; the hot path
now lives in :mod:`repro.engine` — the ring-buffer window carried through a
``lax.scan``, on-device pair compaction, and an async host drain.  What
remains here is the original public surface, preserved for existing
callers and tests:

  * :class:`WindowState` / :func:`init_window` /
    :func:`push_with_overflow` — re-exported from
    :mod:`repro.engine.window` (the unmasked, overflow-blind
    ``push_batch`` is gone: every write path now goes through the policy
    layer and counts live-slot overwrites, DESIGN.md §11);
  * :class:`BlockedJoinConfig` — the historical config dataclass, mapped
    onto :class:`repro.engine.EngineConfig`;
  * :class:`BlockedStreamJoiner` — the synchronous push-and-extract driver,
    now a thin facade: each ``push`` runs the engine's scan step and drains
    the compacted buffers immediately (callers that want pipelining should
    use :class:`repro.engine.StreamEngine` directly).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..engine.engine import EngineConfig, StreamEngine
from ..engine.window import (  # noqa: F401
    WindowState,
    init_window,
    push_with_overflow,
)
from .similarity import time_horizon

__all__ = ["WindowState", "init_window", "BlockedJoinConfig", "BlockedStreamJoiner"]


@dataclasses.dataclass(frozen=True)
class BlockedJoinConfig:
    theta: float
    lam: float
    capacity: int
    d: int
    block_q: int = 128
    block_w: int = 128
    chunk_d: int = 128
    use_ref: bool = False  # route through the jnp oracle instead of Pallas
    max_pairs: int = 4096  # compacted-emission capacity per micro-batch

    @property
    def tau(self) -> float:
        return time_horizon(self.theta, self.lam)

    def to_engine(self, micro_batch: int | None = None) -> EngineConfig:
        # tile_k = block_q·block_w makes level-1 selection lossless, so the
        # wrapper's historical contract survives: the only way to lose a
        # pair is the max_pairs budget, and that raises (see push).  The
        # wrapper also pins join_impl="pallas": it is the kernel-faithful
        # facade, and its pruning telemetry (chunks_executed/tiles_total,
        # consumed by benchmarks/tile_pruning.py) only exists in the kernel
        # — the engine's compiled CPU default ("scan") does not prune.
        return EngineConfig(
            theta=self.theta, lam=self.lam, capacity=self.capacity, d=self.d,
            micro_batch=micro_batch or self.block_q, max_pairs=self.max_pairs,
            tile_k=self.block_q * self.block_w,
            join_impl=None if self.use_ref else "pallas",
            block_q=self.block_q, block_w=self.block_w, chunk_d=self.chunk_d,
            use_ref=self.use_ref,
        )


class BlockedStreamJoiner:
    """Synchronous facade: feeds batches through the engine and returns the
    emitted pairs (uid_a, uid_b, decayed_score) of each push immediately.

    The pre-engine driver was lossless (it fetched the dense score matrix),
    so this wrapper refuses to drop pairs silently: if a push overflows the
    compacted buffer it raises instead of returning a truncated list —
    raise ``cfg.max_pairs`` (bounded by ``micro_batch·(capacity +
    micro_batch)``) or use :class:`repro.engine.StreamEngine` directly and
    handle ``pairs_dropped``.
    """

    def __init__(self, cfg: BlockedJoinConfig) -> None:
        self.cfg = cfg
        self.engine = StreamEngine(cfg.to_engine())

    def push(self, vecs: np.ndarray, ts: np.ndarray):
        before = self.engine.pairs_dropped
        self.engine.push(vecs, ts)
        dropped = self.engine.pairs_dropped - before
        if dropped:
            # raise before draining: the surviving pairs stay queued, so a
            # caller that catches can still recover them via engine.drain_*
            raise RuntimeError(
                f"emission overflow: {dropped} pairs dropped (max_pairs="
                f"{self.cfg.max_pairs} per micro-batch); raise "
                f"BlockedJoinConfig.max_pairs or switch to StreamEngine"
            )
        return self.engine.drain_pairs()

    @property
    def state(self) -> WindowState:
        return self.engine.state

    @property
    def overflow(self) -> int:
        return self.engine.overflow

    @property
    def chunks_executed(self) -> int:
        return self.engine.stats()["chunks_executed"]

    @property
    def tiles_total(self) -> int:
        return self.engine.stats()["tiles_total"]
