"""Instrumentation counters matching the paper's evaluation metrics.

The paper compares algorithms on (i) wall time, (ii) posting entries
traversed during candidate generation (Fig. 2/6), (iii) candidates
generated, and (iv) full similarities computed.  Every index and framework
in :mod:`repro.core` updates one of these counter sets so the benchmark
harness can reproduce the paper's figures.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Counters"]


@dataclasses.dataclass
class Counters:
    items_processed: int = 0
    entries_traversed: int = 0      # posting entries examined in CG
    candidates_generated: int = 0   # distinct candidates reaching CV
    full_sims_computed: int = 0     # residual dot products evaluated
    pairs_emitted: int = 0
    entries_indexed: int = 0        # posting entries ever appended
    entries_pruned: int = 0         # posting entries dropped by time filtering
    reindex_ops: int = 0            # vectors re-scanned due to m updates (AP/L2AP)
    reindex_entries: int = 0        # posting entries appended by re-indexing
    index_rebuilds: int = 0         # MB: number of index (re)constructions
    peak_index_entries: int = 0
    peak_window_items: int = 0

    def merge(self, other: "Counters") -> "Counters":
        out = Counters()
        for f in dataclasses.fields(Counters):
            name = f.name
            if name.startswith("peak_"):
                setattr(out, name, max(getattr(self, name), getattr(other, name)))
            else:
                setattr(out, name, getattr(self, name) + getattr(other, name))
        return out

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)
