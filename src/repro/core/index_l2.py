"""The AP / L2AP / L2 index family (paper §5.2–§5.4, Algorithms 2–4, 6–8).

The paper presents the three schemes as one pseudocode with a color
convention: AP = "red" lines only, L2 = "green" lines only, L2AP = both.
We mirror that with two flags:

  ==========  =========  =========
  scheme      use_ap     use_l2
  ==========  =========  =========
  AP          True       False
  L2AP        True       True
  L2          False      True
  ==========  =========  =========

AP bounds (red) are *data dependent*: they need the dataset max-vector
``m`` (index construction, bound b1), the indexed max-vector ``m̂``
(candidate generation, bound rs1), and per-item stats (size filter sz1,
verification bounds ds1/sz2).  In a stream, growth of ``m`` invalidates the
prefix-filtering invariant and forces *re-indexing* (paper §5.3).

L2 bounds (green) are Cauchy–Schwarz bounds that depend only on prefix
norms of the query and of each indexed vector: pscore b2 = ‖x'‖ (IC),
rs2 = ‖x remaining-prefix‖ and l2bound = C + ‖x'_j‖·‖y'_j‖ (CG), ps1 = C + Q[y]
(CV).  They need *no stream statistics*, which is exactly why the paper's
L2 index is the streaming method of choice: no re-indexing, posting lists
stay time-ordered, truncation is O(1).

Streaming decay placement follows §6.2 precisely:
  * IC: decay is never applied.
  * CG: remscore = min(rs1, rs2·e^{-λΔt}), l2bound gets e^{-λΔt}; for L2AP,
    rs1 is initialized with the *time-decayed* max-vector m̂^λ.
  * CV: every bound and the final test use e^{-λΔt}.
"""

from __future__ import annotations

import math
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Set

import numpy as np

from .counters import Counters
from .postings import ItemMeta, PostingList, ScoreAccumulator
from .similarity import time_horizon
from .types import Pair, SparseVector, StreamItem

__all__ = ["L2FamilyIndex", "Residual"]


class Residual:
    """Entry of the residual direct index R: the un-indexed prefix x' plus
    the stats used by the CV bounds (Alg. 4/8 lines 3–5), and Q[x]."""

    __slots__ = (
        "uid", "t", "indices", "values", "q_pscore",
        "vm", "coord_sum", "nnz", "boundary", "full",
    )

    def __init__(
        self,
        uid: int,
        t: float,
        prefix: SparseVector,
        q_pscore: float,
        boundary: int,
        full: Optional[StreamItem],
    ) -> None:
        self.uid = uid
        self.t = t
        self.indices = prefix.indices
        self.values = prefix.values
        self.q_pscore = q_pscore
        self.vm = prefix.max_value
        self.coord_sum = prefix.coord_sum
        self.nnz = prefix.nnz
        self.boundary = boundary      # first indexed coordinate position
        self.full = full              # full item, kept only when re-indexing is possible


class _DecayedMax:
    """The time-decayed indexed max-vector m̂^λ (paper §5.3).

    Exact lazy maintenance: store per-coordinate ``(value, stamp)``; the
    decayed value at time t is ``value * exp(-λ (t - stamp))``.  Updating
    with a new vector takes O(nnz); a ``dot`` with a query takes O(nnz).
    This works because max and uniform exponential decay commute.
    """

    def __init__(self, lam: float) -> None:
        self.lam = lam
        self.v: Dict[int, float] = {}
        self.stamp: Dict[int, float] = {}

    def value_at(self, j: int, t: float) -> float:
        v = self.v.get(j)
        if v is None:
            return 0.0
        return v * math.exp(-self.lam * (t - self.stamp[j]))

    def update(self, item: StreamItem) -> None:
        t = item.t
        for j, xj in zip(item.vec.indices.tolist(), item.vec.values.tolist()):
            cur = self.value_at(j, t)
            if xj > cur:
                self.v[j] = xj
                self.stamp[j] = t
            elif cur > 0.0:
                self.v[j] = cur
                self.stamp[j] = t


class L2FamilyIndex:
    """AP / L2AP / L2 (static for MB, streaming for STR)."""

    def __init__(
        self,
        theta: float,
        lam: float = 0.0,
        *,
        use_ap: bool,
        use_l2: bool,
        streaming: bool = False,
        counters: Optional[Counters] = None,
    ) -> None:
        if not (use_ap or use_l2):
            raise ValueError("at least one bound family must be enabled")
        if streaming and use_ap and not use_l2:
            # The paper omits STR-AP: "the streaming versions of AP ... are
            # not efficient in practice" (§5.2).
            raise NotImplementedError("STR-AP is not supported (paper §5.2)")
        self.theta = theta
        self.lam = lam
        self.use_ap = use_ap
        self.use_l2 = use_l2
        self.streaming = streaming
        self.tau = time_horizon(theta, lam) if streaming else math.inf
        self.counters = counters if counters is not None else Counters()

        self.lists: Dict[int, PostingList] = {}
        self.meta = ItemMeta()
        self.R: "OrderedDict[int, Residual]" = OrderedDict()
        # AP statistics
        self.m: Dict[int, float] = {}          # dataset / stream max-vector m
        self.mhat: Dict[int, float] = {}       # indexed max-vector m̂ (static CG)
        self.mhat_dec = _DecayedMax(lam)       # m̂^λ (streaming CG)
        self.Rinv: Dict[int, Set[int]] = {}    # inverted index over residuals

        self._arrivals: deque[tuple[int, float]] = deque()
        self._floor_uid = 0
        self._next_uid_hint = 0
        self._n_entries = 0

    @property
    def name(self) -> str:
        return {(True, True): "L2AP", (True, False): "AP", (False, True): "L2"}[
            (self.use_ap, self.use_l2)
        ]

    # ------------------------------------------------------------------ #
    # index construction (Alg. 2 / 6)
    # ------------------------------------------------------------------ #
    def _index_boundary(self, vec: SparseVector) -> tuple[int, float]:
        """Scan coordinates in dimension order, returning ``(p, pscore)``:
        ``p`` = position of the first coordinate to index, ``pscore`` = the
        bound value min(b1, b2) just before that coordinate (stored in Q).

        Indexing starts at the first position where min(b1, b2) computed
        *inclusive* of the coordinate reaches θ (Alg. 2 lines 8–16)."""
        b1 = 0.0
        bt = 0.0
        idx, val = vec.indices, vec.values
        pscore = 0.0
        for k in range(idx.shape[0]):
            # bound value *before* adding coordinate k — candidate Q value
            b1_excl = b1 if self.use_ap else math.inf
            b2_excl = math.sqrt(bt) if self.use_l2 else math.inf
            pre = min(b1_excl, b2_excl)
            j, xj = int(idx[k]), float(val[k])
            if self.use_ap:
                # NOTE: the paper's pseudocode (Alg. 2 line 10, inherited from
                # Bayardo's AP) uses min{m_j, vm_x}.  The vm_x term is only
                # admissible when vectors are processed in decreasing-maxweight
                # order, which a stream processed in *arrival* order cannot
                # guarantee — a later query y with vm_y > vm_x would be missed.
                # We therefore use the order-free bound x_j * m_j (see
                # DESIGN.md "hardware-adaptation notes" / fidelity deviations).
                b1 += xj * self.m.get(j, 0.0)
            bt += xj * xj
            b1_incl = b1 if self.use_ap else math.inf
            b2_incl = math.sqrt(bt) if self.use_l2 else math.inf
            if min(b1_incl, b2_incl) >= self.theta:
                return k, pre
            pscore = pre
        # ‖x‖ = 1 ≥ θ and b1 ≥ ‖x‖² = 1, so the bound always triggers.
        return idx.shape[0], pscore

    def _add_to_index(self, item: StreamItem, keep_full: bool) -> None:
        vec = item.vec
        p, pscore = self._index_boundary(vec)
        prefix = vec.prefix(p)
        self.R[item.uid] = Residual(
            item.uid, item.t, prefix, pscore, p, item if keep_full else None
        )
        if self.use_ap:
            for j in prefix.indices.tolist():
                self.Rinv.setdefault(j, set()).add(item.uid)
        # append suffix coordinates with *exclusive* prefix norms ‖x'_j‖
        csq = float(np.sum(prefix.values * prefix.values))
        for k in range(p, vec.nnz):
            j, xj = int(vec.indices[k]), float(vec.values[k])
            self.lists.setdefault(j, PostingList()).append(
                item.uid, xj, math.sqrt(csq), item.t
            )
            csq += xj * xj
            self._n_entries += 1
        self.counters.entries_indexed += vec.nnz - p
        self.counters.peak_index_entries = max(
            self.counters.peak_index_entries, self._n_entries
        )
        self.meta.add(item.uid, item.t, vec.nnz, vec.max_value)
        self._next_uid_hint = max(self._next_uid_hint, item.uid + 1)
        if self.streaming:
            self._arrivals.append((item.uid, item.t))

    def _update_m_and_reindex(self, item: StreamItem) -> None:
        """Streaming-L2AP re-indexing (paper §5.3).

        When a coordinate of the stream max-vector m grows, the prefix
        filtering invariant no longer covers residuals indexed under the old
        m: their b1 bound was too small, so indexing may now need to start
        earlier.  We locate affected residuals through the residual inverted
        index and move the newly-required coordinates into the posting
        lists (out of time order — which is what costs L2AP its backwards-
        scan fast path, §6.2)."""
        updated: List[int] = []
        for j, xj in zip(item.vec.indices.tolist(), item.vec.values.tolist()):
            if xj > self.m.get(j, 0.0):
                self.m[j] = xj
                updated.append(j)
        if not updated or not self.streaming:
            return
        affected: Set[int] = set()
        for j in updated:
            affected |= self.Rinv.get(j, set())
        for uid in sorted(affected):
            res = self.R.get(uid)
            if res is None or res.full is None:
                continue
            self.counters.reindex_ops += 1
            vec = res.full.vec
            p_new, pscore_new = self._index_boundary(vec)
            p_old = res.boundary
            if p_new > p_old:
                # b1 is monotone in m, so the boundary can only move left;
                # never un-index already-indexed coordinates.
                continue
            if p_new == p_old:
                # Boundary unchanged, but Q[y] was computed under the old m
                # and may now under-bound dot(x, y') — refresh it (a stale Q
                # causes CV's ps1 to prune true pairs).
                res.q_pscore = max(res.q_pscore, pscore_new)
                continue
            # index coordinates p_new .. p_old-1 (the paper's y_{p'} < y_j ≤ y_p)
            prefix_new = vec.prefix(p_new)
            csq = float(np.sum(prefix_new.values * prefix_new.values))
            for k in range(p_new, p_old):
                j, xj = int(vec.indices[k]), float(vec.values[k])
                self.lists.setdefault(j, PostingList()).append(
                    uid, xj, math.sqrt(csq), res.t
                )
                csq += xj * xj
                self._n_entries += 1
                self.counters.reindex_entries += 1
                self.Rinv.get(j, set()).discard(uid)
            new_res = Residual(uid, res.t, prefix_new, pscore_new, p_new, res.full)
            self.R[uid] = new_res

    # ------------------------------------------------------------------ #
    # candidate generation (Alg. 3 / 7)
    # ------------------------------------------------------------------ #
    def _cand_gen(self, item: StreamItem, decayed: bool) -> ScoreAccumulator:
        vec = item.vec
        span = self._next_uid_hint - self._floor_uid + 1
        acc = ScoreAccumulator(self._floor_uid, span)
        if vec.nnz == 0:
            return acc
        t_min = item.t - self.tau
        vm_x = vec.max_value
        sz1 = self.theta / vm_x if (self.use_ap and vm_x > 0) else 0.0

        # rs1 (AP): dot(x, m̂) — static — or dot(x, m̂^λ) — streaming.
        if self.use_ap:
            if decayed:
                mhat_x = np.array(
                    [self.mhat_dec.value_at(int(j), item.t) for j in vec.indices],
                    dtype=np.float64,
                )
            else:
                mhat_x = np.array(
                    [self.mhat.get(int(j), 0.0) for j in vec.indices], dtype=np.float64
                )
            rs1 = float(np.dot(vec.values, mhat_x))
        else:
            rs1 = math.inf
            mhat_x = None

        # rs2 (L2): suffix-exclusive query prefix norms, per scan position.
        rst = 1.0
        # exclusive prefix norms of the query: ‖x'_j‖ for each stored coord
        xsq = vec.values * vec.values
        x_pnorm_excl = np.sqrt(np.maximum(np.concatenate([[0.0], np.cumsum(xsq)[:-1]]), 0.0))

        for k in range(vec.nnz - 1, -1, -1):  # j = d..1, reverse order
            j, xj = int(vec.indices[k]), float(vec.values[k])
            pl = self.lists.get(j)
            if pl is not None and len(pl):
                if decayed:
                    if self.use_ap:
                        # L2AP: lists are NOT time-ordered (re-indexing);
                        # traverse everything, pruning expired entries.
                        self.counters.entries_traversed += len(pl)
                        pruned = pl.filter_expired_unordered(t_min)
                        self.counters.entries_pruned += pruned
                        self._n_entries -= pruned
                    else:
                        # L2: ordered lists ⇒ O(1) truncation, traverse live only.
                        pruned = pl.truncate_before_time(t_min)
                        self.counters.entries_pruned += pruned
                        self._n_entries -= pruned
                        self.counters.entries_traversed += len(pl)
                else:
                    self.counters.entries_traversed += len(pl)
                ids, vals, pnorms, ts = pl.active()
                if ids.size:
                    if decayed:
                        dec = np.exp(-self.lam * np.abs(item.t - ts))
                    else:
                        dec = 1.0
                    rs2 = math.sqrt(max(rst, 0.0)) if self.use_l2 else math.inf
                    remscore = np.minimum(rs1, rs2 * dec) if self.use_l2 else np.full(ids.shape, rs1)
                    pos = ids - acc.base
                    admitted = acc.score[pos] > 0.0
                    if self.use_ap:
                        _, nnz_y, vm_y = self.meta.lookup(ids)
                        size_ok = nnz_y * vm_y >= sz1
                    else:
                        size_ok = True
                    grow = (remscore >= self.theta) & ~acc.killed[pos] & size_ok
                    mask = (admitted | grow) & ~acc.killed[pos]
                    if np.any(mask):
                        upd = pos[mask]
                        acc.score[upd] += xj * vals[mask]
                        acc.touched.append(ids[mask])
                        if self.use_l2:
                            l2b = acc.score[upd] + x_pnorm_excl[k] * pnorms[mask] * (
                                dec[mask] if decayed else 1.0
                            )
                            dead = l2b < self.theta
                            if np.any(dead):
                                acc.killed[upd[dead]] = True
                                acc.score[upd[dead]] = 0.0
            # update running bounds after finishing list j (Alg. 3 lines 14–15)
            if self.use_ap:
                rs1 -= xj * float(mhat_x[k])
            rst -= xj * xj
        return acc

    # ------------------------------------------------------------------ #
    # candidate verification (Alg. 4 / 8)
    # ------------------------------------------------------------------ #
    def _cand_ver(self, item: StreamItem, acc: ScoreAccumulator, decayed: bool) -> List[Pair]:
        cands = acc.candidates()
        self.counters.candidates_generated += int(cands.size)
        if cands.size == 0:
            return []
        out: List[Pair] = []
        vec = item.vec
        vm_x, sum_x, nnz_x = vec.max_value, vec.coord_sum, vec.nnz
        for uid in cands.tolist():
            res = self.R.get(uid)
            if res is None:
                continue  # evicted residual ⇒ out of horizon
            c = float(acc.score[uid - acc.base])
            dec = math.exp(-self.lam * abs(item.t - res.t)) if decayed else 1.0
            ps1 = (c + res.q_pscore) * dec
            if ps1 < self.theta:
                continue
            if self.use_ap:
                ds1 = (c + min(vm_x * res.coord_sum, res.vm * sum_x)) * dec
                sz2 = (c + min(nnz_x, res.nnz) * vm_x * res.vm) * dec
                if ds1 < self.theta or sz2 < self.theta:
                    continue
            # full similarity: accumulated indexed part + residual dot
            self.counters.full_sims_computed += 1
            s = c + _sparse_dot_arrays(
                vec.indices, vec.values, res.indices, res.values
            )
            final = s * dec
            if final >= self.theta:
                out.append(Pair(uid_a=item.uid, uid_b=uid, sim=s, decayed=final))
        self.counters.pairs_emitted += len(out)
        return out

    # ------------------------------------------------------------------ #
    # eviction (time filtering of R / Q / meta)
    # ------------------------------------------------------------------ #
    def _evict(self, now: float) -> None:
        t_min = now - self.tau
        while self._arrivals and self._arrivals[0][1] < t_min:
            uid, _ = self._arrivals.popleft()
            res = self.R.pop(uid, None)
            if res is not None and self.use_ap:
                for j in res.indices.tolist():
                    s = self.Rinv.get(j)
                    if s is not None:
                        s.discard(uid)
            self._floor_uid = uid + 1
        self.meta.rebase(self._floor_uid)
        self.counters.peak_window_items = max(
            self.counters.peak_window_items, len(self._arrivals)
        )

    # ------------------------------------------------------------------ #
    # static (MiniBatch) API
    # ------------------------------------------------------------------ #
    def construct(
        self, items: List[StreamItem], m_global: Optional[Dict[int, float]] = None
    ) -> List[Pair]:
        """IndConstr: build over ``items`` and report raw-similar pairs.

        ``m_global`` is the combined max-vector of the previous and current
        windows (paper §6.1) so the b1 invariant also covers the queries
        that will follow."""
        if self.use_ap and m_global is not None:
            self.m = dict(m_global)
        out: List[Pair] = []
        for item in items:
            if self.use_ap and m_global is None:
                # static self-build without a provided m: grow m first so b1
                # stays admissible for items within this dataset
                for j, xj in zip(item.vec.indices.tolist(), item.vec.values.tolist()):
                    if xj > self.m.get(j, 0.0):
                        self.m[j] = xj
            acc = self._cand_gen(item, decayed=False)
            out.extend(self._cand_ver(item, acc, decayed=False))
            self._add_to_index(item, keep_full=False)
            if self.use_ap:
                for j, xj in zip(item.vec.indices.tolist(), item.vec.values.tolist()):
                    if xj > self.mhat.get(j, 0.0):
                        self.mhat[j] = xj
            self.counters.items_processed += 1
        return out

    def query(self, item: StreamItem) -> List[Pair]:
        acc = self._cand_gen(item, decayed=False)
        self.counters.items_processed += 1
        return self._cand_ver(item, acc, decayed=False)

    # ------------------------------------------------------------------ #
    # streaming (STR) API
    # ------------------------------------------------------------------ #
    def process(self, item: StreamItem) -> List[Pair]:
        """STR main step (Alg. 5/6): CG → CV → index-add (+m upkeep)."""
        assert self.streaming, "process() requires streaming=True"
        self._evict(item.t)
        if self.use_ap:
            # m update + re-indexing BEFORE CG so the invariant holds for x
            self._update_m_and_reindex(item)
        acc = self._cand_gen(item, decayed=True)
        pairs = self._cand_ver(item, acc, decayed=True)
        self._add_to_index(item, keep_full=self.use_ap)
        if self.use_ap:
            self.mhat_dec.update(item)
        self.counters.items_processed += 1
        return pairs


def _sparse_dot_arrays(
    ai: np.ndarray, av: np.ndarray, bi: np.ndarray, bv: np.ndarray
) -> float:
    if ai.size == 0 or bi.size == 0:
        return 0.0
    inter, ia, ib = np.intersect1d(ai, bi, assume_unique=True, return_indices=True)
    if inter.size == 0:
        return 0.0
    return float(np.dot(av[ia], bv[ib]))
