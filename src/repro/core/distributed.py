"""Distributed SSSJ: ring-scheduled join over a sharded window (shard_map).

Scaling the paper's STR framework out: the window ring buffer is sharded
over the mesh ``data`` axis; each device also holds a shard of the incoming
query batch.  Every query shard must meet every window shard, which we
schedule as a **collective-permute ring** (the same schedule as ring
attention / ring all-reduce):

  step s:  prefetch window shard s+1 (ppermute)   ─┐ independent ⇒ XLA's
           join queries vs currently-held shard s ─┘ scheduler overlaps

After P steps every (query, window) pair has been scored exactly once, with
communication fully hidden behind compute for P·t_join ≥ P·t_permute.
Within-batch pairs (query × query across shards) are handled with one
all-gather of the (small) query batch.

The paper's MB-vs-STR memory result inverts at scale: the sharded window's
capacity grows linearly with device count, removing STR's single-host
memory wall (its failure mode in the paper's Table 2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import shard_map
from ..kernels.sssj_join import sssj_join_scores
from .blocked import (
    BlockedJoinConfig,
    WindowState,
    init_window,
    push_with_overflow,
)

__all__ = ["DistributedJoinConfig", "make_distributed_join_step", "init_sharded_window"]


@dataclasses.dataclass(frozen=True)
class DistributedJoinConfig:
    base: BlockedJoinConfig
    axis: str = "data"          # mesh axis the window and batch are sharded over


def init_sharded_window(cfg: DistributedJoinConfig, mesh: Mesh) -> WindowState:
    """Global window of ``base.capacity`` per-shard slots × axis size."""
    n = mesh.shape[cfg.axis]
    state = init_window(cfg.base.capacity * n, cfg.base.d)
    shard = NamedSharding(mesh, P(cfg.axis))
    return WindowState(
        vecs=jax.device_put(state.vecs, NamedSharding(mesh, P(cfg.axis, None))),
        ts=jax.device_put(state.ts, shard),
        uids=jax.device_put(state.uids, shard),
        cursor=jax.device_put(
            jnp.zeros((n,), jnp.int32), shard
        ),  # per-shard cursors
        overflow=jax.device_put(jnp.zeros((n,), jnp.int32), shard),
    )


def make_distributed_join_step(cfg: DistributedJoinConfig, mesh: Mesh):
    """Build the jitted shard_map step.

    Signature: ``(state, q, tq, uq) → (state, (scores_win, scores_self))``
    where ``q`` is the globally-batched query block sharded over ``axis``;
    ``scores_win`` is (B_global, capacity_global) laid out so column block c
    corresponds to window shard c, and ``scores_self`` is (B_global, B_global).
    """
    b = cfg.base
    axis = cfg.axis
    kw = dict(
        theta=b.theta, lam=b.lam, block_q=b.block_q, block_w=b.block_w,
        chunk_d=b.chunk_d, use_ref=b.use_ref,
    )

    p = mesh.shape[axis]

    def local_step(state: WindowState, q, tq, uq):
        # shapes here are per-shard: q (Bl, d); window (Wl, d)
        me = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % p) for i in range(p)]
        wl = state.vecs.shape[0]

        def ring_body(s, carry):
            wv, wt, wu, out = carry
            # prefetch next shard — independent of the join below, so the
            # latency-hiding scheduler overlaps communication with compute
            nwv = jax.lax.ppermute(wv, axis, perm)
            nwt = jax.lax.ppermute(wt, axis, perm)
            nwu = jax.lax.ppermute(wu, axis, perm)
            scores, _ = sssj_join_scores(q, wv, tq, wt, uq, wu, **kw)
            src = (me - s) % p  # global shard id currently held
            out = jax.lax.dynamic_update_slice(
                out, scores, (jnp.int32(0), src * wl)
            )
            return nwv, nwt, nwu, out

        out0 = jnp.zeros((q.shape[0], wl * p), jnp.float32)
        _, _, _, scores_win = jax.lax.fori_loop(
            0, p, ring_body, (state.vecs, state.ts, state.uids, out0)
        )

        # within-batch pairs: all-gather the (small) query shard
        qg = jax.lax.all_gather(q, axis, tiled=True)
        tg = jax.lax.all_gather(tq, axis, tiled=True)
        ug = jax.lax.all_gather(uq, axis, tiled=True)
        scores_self, _ = sssj_join_scores(q, qg, tq, tg, uq, ug, **kw)

        # push this device's query shard into its local window shard —
        # through the policy layer, so the live-slot overwrite accounting
        # is the engine's, not a hand-rolled duplicate (DESIGN.md §11)
        sub = WindowState(
            vecs=state.vecs, ts=state.ts, uids=state.uids,
            cursor=state.cursor[0], overflow=state.overflow[0],
        )
        new_sub = push_with_overflow(
            sub, q, tq, uq, jnp.int32(q.shape[0]), tq.max(), b.tau
        )
        new_state = WindowState(
            vecs=new_sub.vecs, ts=new_sub.ts, uids=new_sub.uids,
            cursor=new_sub.cursor[None],
            overflow=new_sub.overflow[None],
        )
        return new_state, (scores_win, scores_self)

    state_specs = WindowState(
        vecs=P(axis, None), ts=P(axis), uids=P(axis), cursor=P(axis), overflow=P(axis)
    )
    shard_fn = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(state_specs, P(axis, None), P(axis), P(axis)),
        out_specs=(state_specs, (P(axis, None), P(axis, None))),
        check_vma=False,
    )
    return jax.jit(shard_fn, donate_argnums=(0,))
