"""The Streaming (STR) framework — paper Algorithm 5.

STR is a thin driver: every stream item is pushed through the streaming
index, which internally performs time-filtered candidate generation,
decayed verification, and index maintenance (lazy posting-list pruning,
residual eviction, and — for L2AP — max-vector upkeep with re-indexing).
"""

from __future__ import annotations

from typing import List, Optional

from .counters import Counters
from .types import Pair, StreamItem

__all__ = ["StreamingJoiner"]


class StreamingJoiner:
    """STR-IDX: incremental index with time filtering pushed inside."""

    def __init__(self, index, counters: Optional[Counters] = None) -> None:
        if not getattr(index, "streaming", False):
            raise ValueError("StreamingJoiner requires a streaming-mode index")
        self.index = index
        if counters is not None:
            index.counters = counters
        self.counters = index.counters

    def push(self, item: StreamItem) -> List[Pair]:
        return self.index.process(item)

    def finish(self) -> List[Pair]:
        return []
