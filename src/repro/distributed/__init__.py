from .sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    axis_ctx,
    constrain,
    resolve_pspec,
    param_shardings,
    shard_map,
    use_rules,
)
