"""Logical-axis sharding: named axes → mesh axes with safe fallbacks.

Model code annotates parameters and activations with *logical* axis names
("vocab", "ff", "heads", "experts", "batch", "kv_seq", ...).  A rule table
maps logical names to mesh axes; :func:`resolve_pspec` applies the table
with a divisibility check — a dimension that does not divide evenly over
its assigned mesh axes falls back to replication rather than relying on
GSPMD padding (padding waste is opt-in via ``allow_uneven``).

The active (mesh, rules) pair is held in a context (:func:`use_rules`);
model code calls :func:`constrain` freely — it is a no-op outside the
context, so single-device smoke tests run the same code path.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax ≥ 0.6 exposes shard_map at the top level
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x: experimental module, check_rep spelling
    import functools

    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_exp(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )

    functools.update_wrapper(shard_map, _shard_map_exp)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "axis_ctx",
    "use_rules",
    "constrain",
    "resolve_pspec",
    "param_shardings",
    "shard_map",
]

MeshAxes = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical-name → mesh axis (or tuple of axes) table."""

    table: Dict[str, MeshAxes]
    allow_uneven: Tuple[str, ...] = ()   # logical names where GSPMD padding is OK

    def lookup(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.table.get(logical)

    def override(self, **kw: MeshAxes) -> "AxisRules":
        t = dict(self.table)
        t.update(kw)
        return AxisRules(t, self.allow_uneven)


# The production meshes are (data=16, model=16) and (pod=2, data=16, model=16);
# "batch" spans pod×data so the same rules serve both (missing axes are
# dropped at resolve time).
DEFAULT_RULES = AxisRules(
    table={
        "batch": ("pod", "data"),
        "seq": None,
        "kv_seq": None,          # set to ("data",) for long-context decode
        "d_model": None,
        "ff": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": None,
        "vocab": ("model",),
        "experts": ("model",),
        "expert_ff": None,
        "fsdp": ("data",),       # parameter/optimizer-state sharding (ZeRO)
        "layers": None,
        "state": None,
        "window": ("data",),     # SSSJ ring-buffer shards (engine/sharded.py)
    },
    # NOTE: no allow_uneven entries — jit *input* shardings must divide
    # exactly, so an indivisible dim (e.g. 56 heads over model=16, or 8 kv
    # heads over 16) falls back to replication.  The per-arch consequences
    # are recorded in EXPERIMENTS.md §Dry-run.
    allow_uneven=(),
)


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[AxisRules] = None


axis_ctx = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    prev = (axis_ctx.mesh, axis_ctx.rules)
    axis_ctx.mesh, axis_ctx.rules = mesh, rules
    try:
        yield
    finally:
        axis_ctx.mesh, axis_ctx.rules = prev


def _mesh_axes_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def _present_axes(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes that do not exist in this mesh (e.g. 'pod' on 1 pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    kept = tuple(a for a in axes if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def resolve_pspec(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: Optional[AxisRules] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Map a tuple of logical names to a PartitionSpec for ``shape``.

    Dimensions whose size does not divide the assigned mesh-axes product
    are replicated unless the logical name is in ``rules.allow_uneven``.
    """
    rules = rules or axis_ctx.rules
    mesh = mesh or axis_ctx.mesh
    if rules is None or mesh is None:
        return P()
    assert len(shape) == len(logical), (shape, logical)
    out = []
    used: set = set()   # a mesh axis may appear at most once per spec
    for dim, name in zip(shape, logical):
        axes = _present_axes(mesh, rules.lookup(name))
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        # cross-dim conflict resolution: earlier dims win, later dims drop
        # already-claimed mesh axes (e.g. kv_seq→model before kv_heads→model)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            out.append(None)
            continue
        size = _mesh_axes_size(mesh, axes)
        if size <= 1:
            out.append(None)
        elif dim % size == 0 or (name in rules.allow_uneven):
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without a context."""
    rules, mesh = axis_ctx.rules, axis_ctx.mesh
    if rules is None or mesh is None:
        return x
    spec = resolve_pspec(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(spec_tree, shape_tree, mesh: Mesh, rules: AxisRules):
    """Build a NamedSharding pytree from a logical-spec tree.

    ``spec_tree`` mirrors the param tree, with a tuple of logical names
    (or None) per leaf; ``shape_tree`` supplies leaf shapes
    (jax.ShapeDtypeStruct or arrays).
    """

    def one(spec, leaf):
        shape = leaf.shape
        if spec is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, resolve_pspec(shape, spec, rules, mesh))

    return jax.tree.map(
        one, spec_tree, shape_tree, is_leaf=lambda s: s is None or isinstance(s, tuple)
    )
