"""Metrics registry: counters, gauges, and fixed log-bucket histograms.

One registry instance is the single stats surface for a whole serving
stack (DESIGN.md §12): every layer — kernel-telemetry plumbing, engine,
sharded fan-out, multi-tenant runtime, serving facade, and the host-side
paper :class:`~repro.core.counters.Counters` — publishes into it under
namespaced keys (``engine/…``, ``router/…``, ``tenant/<k>/…``,
``span/<stage>/…``, ``paper/…``).

Two publishing styles coexist:

  * **live instruments** — ``registry.counter(name).inc()`` /
    ``registry.histogram(name).observe(v)`` for host-side events as they
    happen (span timings, latency observations);
  * **collectors** — ``registry.register_collector(fn)`` for state that
    lives elsewhere (device telemetry carries, router telemetry
    dataclasses): ``fn(registry)`` runs at :meth:`MetricsRegistry.snapshot`
    time and ``.set()``\\ s the current totals, so a snapshot is always
    coherent with the device state at the moment it is taken.

Snapshots are plain JSON-able dicts (histograms expand to
``{"bounds", "counts", "sum", "count"}``) and round-trip losslessly
through :func:`json.dumps`; :meth:`MetricsRegistry.prometheus_text`
renders the same data in Prometheus text exposition format (histograms
as cumulative ``_bucket{le=…}`` series).

Nothing in this module touches jax: it is importable from any layer
(including inside the drain copy-thread) without triggering backend
initialization.
"""

from __future__ import annotations

import bisect
import json
import math
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Info",
    "LATENCY_BOUNDS_S",
    "MetricsRegistry",
    "histogram_percentile",
    "log_buckets",
    "merge_disjoint",
]

Number = Union[int, float]


def log_buckets(lo: float, hi: float, growth: float = 2.0) -> Tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds ``lo, lo·g, lo·g², … ≥ hi``.

    The first bound is exactly ``lo`` and bounds grow by repeated
    multiplication (no rounding), so bucket boundaries are reproducible
    floats — a value observed exactly at a boundary lands in the bucket
    whose upper bound equals it (``le`` semantics, as in Prometheus).
    """
    if not (lo > 0.0 and hi > lo and growth > 1.0):
        raise ValueError(
            f"need 0 < lo < hi and growth > 1, got lo={lo} hi={hi} "
            f"growth={growth}"
        )
    out: List[float] = []
    b = float(lo)
    while b < hi:
        out.append(b)
        b *= growth
    out.append(b)                      # first bound ≥ hi closes the range
    return tuple(out)


# admission→emission latency vocabulary: 10 µs … ~84 s in ×2 steps
LATENCY_BOUNDS_S: Tuple[float, ...] = log_buckets(1e-5, 64.0, 2.0)


class Counter:
    """Monotonic total.  ``inc`` for live events; ``set`` for collectors
    that re-publish an externally-owned total (device telemetry) at
    snapshot time."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def set(self, v: Number) -> None:
        self.value = v

    def read(self) -> Number:
        return self.value


class Gauge:
    """Point-in-time reading (queue depth, ring liveness, ratios)."""

    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def read(self) -> Number:
        return self.value


class Info:
    """String-valued metric (policy names, modes).  Rendered in
    Prometheus exposition as a ``…_info{value="…"} 1`` series."""

    __slots__ = ("name", "value")
    kind = "info"

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: str = ""

    def set(self, v: str) -> None:
        self.value = str(v)

    def read(self) -> str:
        return self.value


class Histogram:
    """Fixed-bucket histogram with ``le`` (inclusive-upper) semantics.

    ``counts[i]`` holds observations in ``(bounds[i-1], bounds[i]]``
    (``(-inf, bounds[0]]`` for ``i = 0``); ``counts[-1]`` is the +inf
    overflow bucket.  Bounds are fixed at construction —
    :data:`LATENCY_BOUNDS_S` by default — so histograms merged across
    snapshots or tenants always share boundaries.
    """

    __slots__ = ("name", "bounds", "counts", "sum", "count")
    kind = "histogram"

    def __init__(
        self, name: str, bounds: Sequence[float] = LATENCY_BOUNDS_S
    ) -> None:
        bounds = tuple(float(b) for b in bounds)
        if not bounds or any(
            b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])
        ):
            raise ValueError(f"bounds must be strictly increasing: {bounds}")
        self.name = name
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def observe_many(self, values: np.ndarray) -> None:
        values = np.asarray(values, np.float64).reshape(-1)
        if values.size == 0:
            return
        idx = np.searchsorted(self.bounds, values, side="left")
        for i, c in zip(*np.unique(idx, return_counts=True)):
            self.counts[int(i)] += int(c)
        self.sum += float(values.sum())
        self.count += int(values.size)

    def percentile(self, q: float) -> float:
        """Bucket-interpolated percentile, ``q ∈ [0, 1]``; 0.0 if empty."""
        return histogram_percentile(
            {"bounds": self.bounds, "counts": self.counts, "count": self.count},
            q,
        )

    def read(self) -> dict:
        return {
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "sum": self.sum,
            "count": self.count,
        }


def histogram_percentile(h: dict, q: float) -> float:
    """Percentile from a snapshot-form histogram dict (``bounds``,
    ``counts``, ``count``), linearly interpolated inside the bucket; the
    overflow bucket reports its lower bound (no honest upper edge)."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    count = h["count"]
    if count == 0:
        return 0.0
    bounds, counts = h["bounds"], h["counts"]
    target = q * count
    cum = 0
    for i, c in enumerate(counts):
        if cum + c >= target and c > 0:
            lo = 0.0 if i == 0 else bounds[i - 1]
            if i >= len(bounds):            # +inf overflow bucket
                return float(bounds[-1])
            frac = (target - cum) / c
            return float(lo + frac * (bounds[i] - lo))
        cum += c
    return float(bounds[-1])


def merge_disjoint(*dicts: dict) -> dict:
    """Merge stats dicts, refusing silent key collisions (a colliding key
    means two layers published under the same name — one of them must
    namespace)."""
    out: dict = {}
    for d in dicts:
        clash = out.keys() & d.keys()
        if clash:
            raise ValueError(
                f"stats key collision across layers: {sorted(clash)}; "
                f"namespace the keys at the publishing layer"
            )
        out.update(d)
    return out


_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    n = _PROM_NAME.sub("_", name)
    return "_" + n if n[:1].isdigit() else n


def _prom_num(v: Number) -> str:
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return str(v)


class MetricsRegistry:
    """Create-or-get metric instruments plus snapshot-time collectors.

    Instrument getters are idempotent: asking for an existing name
    returns the existing instrument (and raises if the kind differs —
    a kind change is a schema break, not a merge).
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        self._collectors: List[Callable[["MetricsRegistry"], None]] = []

    # ------------------------------------------------------------------ #
    def _get(self, cls, name: str, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
            return m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(Counter, name)

    def gauge(self, name: str) -> Gauge:
        return self._get(Gauge, name)

    def info(self, name: str) -> Info:
        return self._get(Info, name)

    def histogram(
        self, name: str, bounds: Optional[Sequence[float]] = None
    ) -> Histogram:
        if bounds is None:
            return self._get(Histogram, name, LATENCY_BOUNDS_S)
        h = self._get(Histogram, name, bounds)
        if tuple(float(b) for b in bounds) != h.bounds:
            raise ValueError(
                f"histogram {name!r} already registered with different "
                f"bounds"
            )
        return h

    def register_collector(
        self, fn: Callable[["MetricsRegistry"], None]
    ) -> None:
        """``fn(registry)`` runs (in registration order) at the start of
        every :meth:`snapshot` to publish externally-owned state."""
        self._collectors.append(fn)

    # ------------------------------------------------------------------ #
    def collect(self) -> None:
        for fn in self._collectors:
            fn(self)

    def schema(self) -> Dict[str, str]:
        """``{name: kind}`` for every registered metric (collectors run
        first so lazily-created instruments are included)."""
        self.collect()
        return {name: m.kind for name, m in sorted(self._metrics.items())}

    def snapshot(self) -> dict:
        """One coherent ``{name: value}`` view of every metric; histogram
        values expand to their bucket dicts.  JSON-serializable as-is."""
        self.collect()
        return {name: m.read() for name, m in sorted(self._metrics.items())}

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    def prometheus_text(self) -> str:
        """Prometheus text exposition of the current snapshot."""
        snap = self.snapshot()
        kinds = {name: m.kind for name, m in self._metrics.items()}
        lines: List[str] = []
        for name, value in snap.items():
            pname, kind = _prom_name(name), kinds[name]
            if kind == "info":
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f'{pname}{{value="{value}"}} 1')
            elif kind == "histogram":
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for b, c in zip(value["bounds"], value["counts"]):
                    cum += c
                    lines.append(
                        f'{pname}_bucket{{le="{_prom_num(float(b))}"}} {cum}'
                    )
                lines.append(
                    f'{pname}_bucket{{le="+Inf"}} {value["count"]}'
                )
                lines.append(f"{pname}_sum {_prom_num(value['sum'])}")
                lines.append(f"{pname}_count {value['count']}")
            else:
                lines.append(f"# TYPE {pname} {kind}")
                lines.append(f"{pname} {_prom_num(value)}")
        return "\n".join(lines) + "\n"
