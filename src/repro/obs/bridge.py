"""Bridges from legacy stats surfaces into the metrics registry.

Two kinds of pre-registry vocabulary exist in the tree:

  * the paper's host-side :class:`~repro.core.counters.Counters`
    (entries traversed, candidates generated, full similarities — the
    Fig. 2/6 vocabulary), owned by the reference indexes in
    :mod:`repro.core`;
  * flat namespaced dicts computed from device state at snapshot time
    (e.g. :func:`repro.engine.sharded.shard_metrics`).

Both publish through here so the paper's metrics and the engine's
telemetry land in one snapshot under one naming scheme.
"""

from __future__ import annotations

import dataclasses

from .registry import MetricsRegistry

__all__ = ["publish_counters", "publish_flat"]

# flat-dict keys whose last path segment names a point-in-time reading
# (everything else a flat publisher emits is a monotonic total)
_GAUGE_LEAVES = frozenset({"live_slots", "cursor", "n_shards"})


def publish_counters(
    registry: MetricsRegistry, counters, prefix: str = "paper"
) -> None:
    """Register a collector republishing a paper
    :class:`~repro.core.counters.Counters` under ``paper/<field>`` keys.

    The dataclass stays the live owner — the collector re-reads it at
    every snapshot, so one ``Counters`` threaded through a reference
    joiner keeps the registry current with no further calls.  ``peak_*``
    fields publish as gauges (they are maxima, not totals).
    """
    fields = [f.name for f in dataclasses.fields(type(counters))]

    def collect(reg: MetricsRegistry) -> None:
        for name in fields:
            v = getattr(counters, name)
            if name.startswith("peak_"):
                reg.gauge(f"{prefix}/{name}").set(v)
            else:
                reg.counter(f"{prefix}/{name}").set(v)

    registry.register_collector(collect)


def publish_flat(registry: MetricsRegistry, flat: dict) -> None:
    """Publish a flat ``{namespaced_key: number}`` dict, classifying each
    key as gauge or counter by its leaf name (see ``_GAUGE_LEAVES``)."""
    for name, v in flat.items():
        leaf = name.rsplit("/", 1)[-1]
        if leaf in _GAUGE_LEAVES:
            registry.gauge(name).set(v)
        else:
            registry.counter(name).set(v)
