"""repro.obs — unified observability: registry, spans, latency (§12).

One :class:`MetricsRegistry` per serving stack; every layer publishes
into it under namespaced keys and the legacy ``stats()`` dicts become
compatibility views over the same snapshot.
"""

from __future__ import annotations

from .bridge import publish_counters, publish_flat
from .registry import (
    Counter,
    Gauge,
    Histogram,
    Info,
    LATENCY_BOUNDS_S,
    MetricsRegistry,
    histogram_percentile,
    log_buckets,
    merge_disjoint,
)
from .spans import PIPELINE_STAGES, SpanTracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Info",
    "LATENCY_BOUNDS_S",
    "MetricsRegistry",
    "PIPELINE_STAGES",
    "SpanTracer",
    "histogram_percentile",
    "log_buckets",
    "merge_disjoint",
    "publish_counters",
    "publish_flat",
]
