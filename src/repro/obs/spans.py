"""Pipeline span tracing: per-stage wall time for the serving path.

The serving pipeline is a fixed sequence of host-side stages —
``admit → coalesce → h2d → scan → drain → emit`` (DESIGN.md §12) — and
each stage's wall time accumulates into the shared
:class:`~repro.obs.registry.MetricsRegistry` under ``span/<stage>/time_s``
(a float counter) and ``span/<stage>/calls``, so a snapshot attributes
the host budget stage by stage.

Timing uses :func:`time.monotonic`.  Two caveats the keys are named
around:

  * ``scan`` measures the *dispatch* of the jitted step, not device
    execution — jax dispatch is asynchronous, so device time hides
    inside whichever later stage first blocks on the result (normally
    ``drain``, the copy-thread D2H materialization, recorded via
    :meth:`SpanTracer.record` with a duration measured on that thread);
  * for real device-side attribution, wrap a region in
    :meth:`SpanTracer.jax_trace` — a guarded hook around
    ``jax.profiler`` trace capture that degrades to a no-op when the
    profiler is unavailable (e.g. headless CI without tensorboard).
"""

from __future__ import annotations

import contextlib
import time
from typing import Iterator, Tuple

from .registry import MetricsRegistry

__all__ = ["PIPELINE_STAGES", "SpanTracer"]

# canonical serving-pipeline stage names, in pipeline order
PIPELINE_STAGES: Tuple[str, ...] = (
    "admit", "coalesce", "h2d", "scan", "drain", "emit",
)


class SpanTracer:
    """Accumulate per-stage wall time into a metrics registry."""

    def __init__(self, registry: MetricsRegistry, prefix: str = "span") -> None:
        self.registry = registry
        self.prefix = prefix

    def record(self, stage: str, seconds: float) -> None:
        """Record one completed span measured elsewhere (e.g. on the
        drain copy thread, whose duration is stamped by the worker)."""
        p = f"{self.prefix}/{stage}"
        self.registry.counter(f"{p}/calls").inc(1)
        self.registry.counter(f"{p}/time_s").inc(float(seconds))

    @contextlib.contextmanager
    def span(self, stage: str) -> Iterator[None]:
        """Time a pipeline stage: ``with tracer.span("coalesce"): …``."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.record(stage, time.monotonic() - t0)

    @contextlib.contextmanager
    def jax_trace(self, logdir: str) -> Iterator[bool]:
        """Capture a ``jax.profiler`` trace of the wrapped region into
        ``logdir`` (viewable in TensorBoard/Perfetto).  Yields whether
        capture actually started; degrades to a no-op — never an error —
        when the profiler backend is unavailable, so callers can leave
        the hook in place unconditionally."""
        started = False
        try:
            import jax

            jax.profiler.start_trace(logdir)
            started = True
        except Exception:
            started = False
        try:
            yield started
        finally:
            if started:
                with contextlib.suppress(Exception):
                    import jax

                    jax.profiler.stop_trace()
            self.registry.counter(f"{self.prefix}/jax_traces").inc(
                1 if started else 0
            )
