"""repro.engine — the device-resident streaming join engine.

Layers (DESIGN.md §4–§5):

  * :mod:`~repro.engine.window` — policy-driven ring-buffer window
    primitives shared by every driver (the device form of the paper's
    circular posting lists, with pluggable write-slot/eviction policies);
  * :mod:`~repro.engine.engine` — :class:`StreamEngine`: ``lax.scan`` over
    micro-batches with donated carry, on-device pair compaction, async
    host drain;
  * :mod:`~repro.engine.sharded` — :class:`ShardedStreamEngine`: one ring
    shard per device (``"window"`` logical axis), broadcast queries,
    gathered compacted buffers.

:mod:`repro.core.blocked` remains as a thin compatibility wrapper.
"""

from .engine import (  # noqa: F401
    EngineConfig,
    EngineTelemetry,
    StreamEngine,
    StreamEngineBase,
    make_batch_step,
    make_micro_step,
)
from .sharded import (  # noqa: F401
    ShardedStreamEngine,
    init_sharded_window,
    make_sharded_batch_step,
    shard_metrics,
    shard_stats,
    shard_view,
    window_axis,
)
from .window import (  # noqa: F401
    EVICTION_POLICIES,
    WindowState,
    init_window,
    push_with_overflow,
    quota_partition,
    select_write_slots,
)
