"""Device-resident streaming join engine: one jit call per request batch.

The previous host driver (:class:`repro.core.blocked.BlockedStreamJoiner`)
re-entered jit once per micro-batch, fetched the dense ``(B, capacity)`` +
``(B, B)`` score matrices to the host, and extracted pairs in a Python
``np.nonzero`` loop — throughput was bounded by PCIe and the GIL, not the
MXU.  The engine restores the paper's invariant that candidate generation,
time filtering, and verification never leave the index's hot loop:

  * the ring-buffer :class:`WindowState` is carried through a single
    ``lax.scan`` over micro-batches (one jit call — and one device
    round-trip of *control*, not data — per request batch, donated state);
  * emission is **hierarchically compacted** on device (DESIGN.md §3): each
    kernel tile selects its own ≥ θ entries into a ``(tile_k,)`` candidate
    buffer (level 1, inside the join), and a segmented scan + gather merges
    the per-tile buffers into the global ``(max_pairs,)``
    :class:`~repro.kernels.sssj_join.compact.PairBuffer` (level 2) — the
    dense ``(B, capacity)`` score matrix is never written to HBM and
    nothing ever sorts ``O(B·capacity)`` elements.  The PR-1 dense pipeline
    survives behind ``emit_dense=True`` as the test oracle;
  * a per-row **match mask** (``row i has ≥ θ match``, exact even under
    candidate overflow) rides along for consumers that only need
    membership, not pairs (e.g. the dedup filter) — O(B) with no
    truncation risk;
  * the host drain is asynchronous *and off-thread*: :meth:`StreamEngine
    .push` dispatches the scan and hands the device buffers to a
    single-worker copy thread, so the D2H copies of batch *n* overlap the
    device compute of batch *n+1*; pairs materialize on the host only when
    the caller asks (:meth:`drain_arrays` / :meth:`drain_pairs`).

Telemetry (pruning iterations, emitted pair counts, and the per-level drop
counters — ``tile_k`` overflow vs ``max_pairs`` overflow) accumulates
in-carry as device scalars and is summed on the host only at
:meth:`stats` time.

The scan body (:func:`make_micro_step`) and the host facade
(:class:`StreamEngineBase`) are shared with the sharded fan-out
(:mod:`repro.engine.sharded`): the sharded variant differs only in which
rows each device ingests, in emitting self-join pairs on one shard, and in
adding a third merge level (per-shard buffers → one global budget).
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.similarity import time_horizon
from ..obs import MetricsRegistry
from ..kernels.sssj_join import (
    PairBuffer,
    compact_pairs,
    concat_candidates,
    merge_candidates,
    sssj_join_candidates,
    sssj_join_tiles,
)
from .window import (
    EVICTION_POLICIES,
    WindowState,
    init_window,
    push_with_overflow,
)

__all__ = [
    "EngineConfig",
    "EngineTelemetry",
    "StreamEngine",
    "StreamEngineBase",
    "make_batch_step",
    "make_micro_step",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    theta: float
    lam: float
    capacity: int
    d: int
    micro_batch: int = 128       # scan step size; requests are padded up
    max_pairs: int = 4096        # compacted-emission capacity per micro-batch
    tile_k: int = 256            # level-1 candidates kept per kernel tile
    shard_k: Optional[int] = None  # per-shard merge capacity (sharded engine);
    #                                None → max_pairs
    block_q: int = 128
    block_w: int = 128
    chunk_d: int = 128
    emit_dense: bool = False     # PR-1 dense-matrix compaction (test oracle)
    join_impl: Optional[str] = None  # candidate impl: pallas/scan/dense; None=auto
    use_ref: bool = False        # route joins through the jnp oracle
    interpret: Optional[bool] = None
    eviction: str = "oldest"     # write-slot policy: oldest/dead/quota (§11)
    quotas: Optional[Tuple[int, ...]] = None  # per-stream slots (quota policy);
    #                                           sums to capacity (per shard)
    l2_gate: Optional[bool] = None  # L2/prefix strip-summary gate (§13):
    #   True = on, False = off, None = auto (on for every hierarchical
    #   non-dense join path, where the gate can actually skip launches)

    def __post_init__(self) -> None:
        """Reject configurations that would only fail later as opaque shape
        or tracer errors deep inside the jitted scan."""
        if not 0.0 < self.theta <= 1.0:
            raise ValueError(f"theta must be in (0, 1], got {self.theta}")
        if self.lam < 0.0:
            raise ValueError(f"lam must be ≥ 0, got {self.lam}")
        for name in ("capacity", "d", "micro_batch", "max_pairs", "tile_k",
                     "block_q", "block_w", "chunk_d"):
            v = getattr(self, name)
            if (isinstance(v, bool) or not isinstance(v, (int, np.integer))
                    or v < 1):
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if self.shard_k is not None and self.shard_k < 1:
            raise ValueError(f"shard_k must be ≥ 1, got {self.shard_k}")
        if self.micro_batch > self.capacity:
            raise ValueError(
                f"micro_batch ({self.micro_batch}) exceeds window capacity "
                f"({self.capacity}): a single micro-batch would overwrite "
                f"its own arrivals; raise capacity or lower micro_batch"
            )
        # the join pads rows/features up to block multiples, so any
        # block_q/block_w/chunk_d is shape-safe — but a padded query tile
        # must still exist: blocks have to fit the padded micro-batch,
        # i.e. be at most the next block_q-multiple of micro_batch (always
        # true) and positive (checked above).  What CAN break downstream
        # is an impl contradiction:
        if self.use_ref and self.join_impl in ("pallas", "scan"):
            raise ValueError(
                f"use_ref routes joins through the dense jnp oracle and "
                f"contradicts join_impl={self.join_impl!r}; drop one"
            )
        if self.join_impl not in (None, "pallas", "scan", "dense"):
            raise ValueError(
                f"join_impl must be one of None/'pallas'/'scan'/'dense', "
                f"got {self.join_impl!r}"
            )
        if self.l2_gate is True and (
            self.emit_dense or self.use_ref or self.join_impl == "dense"
        ):
            raise ValueError(
                "l2_gate=True requires a gated join path; the dense oracle "
                "(emit_dense / use_ref / join_impl='dense') never consults "
                "the gate — drop l2_gate or leave it None"
            )
        if self.eviction not in EVICTION_POLICIES:
            raise ValueError(
                f"eviction must be one of {EVICTION_POLICIES}, "
                f"got {self.eviction!r}"
            )
        if self.quotas is not None:
            if self.eviction != "quota":
                raise ValueError(
                    f"quotas are only meaningful under eviction='quota' "
                    f"(got eviction={self.eviction!r})"
                )
            qs = tuple(self.quotas)
            for i, v in enumerate(qs):
                if (isinstance(v, bool) or not isinstance(v, (int, np.integer))
                        or v < 1):
                    raise ValueError(
                        f"quotas[{i}] must be a positive int, got {v!r}"
                    )
            if sum(int(v) for v in qs) != self.capacity:
                raise ValueError(
                    f"quotas must sum to capacity ({self.capacity}), got "
                    f"{sum(int(v) for v in qs)} over {len(qs)} streams"
                )
            object.__setattr__(self, "quotas", tuple(int(v) for v in qs))
        elif self.eviction == "quota":
            raise ValueError("eviction='quota' requires a quotas table")

    @property
    def tau(self) -> float:
        return time_horizon(self.theta, self.lam)

    @property
    def gate_enabled(self) -> bool:
        """Whether the window state carries a strip summary and the join
        runs the L2/prefix pre-launch gate (DESIGN.md §13)."""
        if self.l2_gate is not None:
            return bool(self.l2_gate)
        return not (
            self.emit_dense or self.use_ref or self.join_impl == "dense"
        )

    @property
    def n_lanes(self) -> Optional[int]:
        """Stream-lane count the window state must carry for this config
        (from the quota table; the multi-tenant runtime widens it to its
        tenant count so per-victim overflow attribution works under any
        policy)."""
        return None if self.quotas is None else len(self.quotas)

    def quotas_device(self) -> Optional[jax.Array]:
        """The quota table as a device array (``None`` off-quota) — what
        the write-slot policy consumes inside the jitted step."""
        return (
            None if self.quotas is None
            else jnp.asarray(self.quotas, jnp.int32)
        )

    @property
    def join_kwargs(self) -> dict:
        """kwargs for the dense-emission join (``emit_dense`` oracle path)."""
        return dict(
            theta=self.theta, lam=self.lam, block_q=self.block_q,
            block_w=self.block_w, chunk_d=self.chunk_d, use_ref=self.use_ref,
            interpret=self.interpret,
        )

    @property
    def candidate_kwargs(self) -> dict:
        """kwargs for the hierarchical join (default path)."""
        impl = self.join_impl
        if impl is None and self.use_ref:
            impl = "dense"
        return dict(
            theta=self.theta, lam=self.lam, tile_k=self.tile_k,
            block_q=self.block_q, block_w=self.block_w, chunk_d=self.chunk_d,
            impl=impl, interpret=self.interpret,
        )


class EngineTelemetry(NamedTuple):
    """Device-resident counters accumulated in the scan carry.

    ``chunks``/``tiles`` count the *window* join only (self-join tiles have
    near-zero time deltas and would dilute the pruning signal) — the same
    accounting the pre-engine driver used, so ``benchmarks/tile_pruning.py``
    numbers stay comparable across versions.  Drops are split by level so
    an operator can tell an undersized ``tile_k`` from an undersized
    ``max_pairs``.
    """

    chunks: jax.Array        # () i32 — d-chunks executed (pruning telemetry)
    tiles: jax.Array         # () i32 — window-join tiles visited
    pairs: jax.Array         # () i32 — pairs emitted (compacted, post-merge)
    dropped: jax.Array       # () i32 — pairs lost to the max_pairs budget
    dropped_tile: jax.Array  # () i32 — pairs lost to per-tile/per-shard caps
    tiles_skipped_time: jax.Array  # () i32 — gate kills by the time bound
    tiles_skipped_l2: jax.Array    # () i32 — gate kills by the value bounds
    strips_survived: jax.Array     # () i32 — strips the gated walk visited


def init_telemetry() -> EngineTelemetry:
    # distinct buffers: the step donates the whole pytree, and donating one
    # buffer twice is an error
    return EngineTelemetry(
        *(jnp.zeros((), jnp.int32) for _ in EngineTelemetry._fields)
    )


def pad_request(vecs, ts, next_uid: int, micro_batch: int):
    """Host-side request prep shared by both engines: assign uids, pad the
    batch to a micro-batch multiple (pad rows carry ``uid = -1`` so the
    kernel order mask silences them; pad timestamps repeat the last valid
    one), and reshape into scan inputs.

    Returns ``(uq, qs, tqs, uqs, nvs)``: the assigned uids ``(b,)`` plus
    the scan stacks ``(n_micro, mb, ·)`` and valid-row counts ``(n_micro,)``
    (``nvs`` stays a host array — the drain needs it to unpad row masks).
    """
    vecs = np.asarray(vecs, np.float32)
    ts = np.asarray(ts, np.float32).reshape(-1)
    b = vecs.shape[0]
    uq = np.arange(next_uid, next_uid + b, dtype=np.int32)
    mb = micro_batch
    n_micro = -(-b // mb)
    pad = n_micro * mb - b
    if pad:
        vecs = np.concatenate([vecs, np.zeros((pad, vecs.shape[1]), np.float32)])
        ts = np.concatenate([ts, np.full(pad, ts[-1], np.float32)])
        uq_in = np.concatenate([uq, np.full(pad, -1, np.int32)])
    else:
        uq_in = uq
    nvs = np.full(n_micro, mb, np.int32)
    nvs[-1] = mb - pad
    return (
        uq,
        jnp.asarray(vecs.reshape(n_micro, mb, -1)),
        jnp.asarray(ts.reshape(n_micro, mb)),
        jnp.asarray(uq_in.reshape(n_micro, mb)),
        nvs,
    )


def make_micro_step(
    cfg: EngineConfig,
    ingest: Callable,
    self_mask: Optional[Callable] = None,
    tenant_lookup: Optional[Callable] = None,
    embed_fn: Optional[Callable] = None,
):
    """Build the scan body shared by the single-device, sharded, and
    multi-tenant engines.

    ``ingest(state, q, tq, uq, n_valid, t_max[, sq]) → new state`` pushes
    this micro-batch (or the shard's slice of it) into the ring with
    overflow accounting; ``self_mask`` optionally suppresses the
    within-batch candidates (``PairCandidates → PairCandidates``; the
    sharded engine emits them on one shard only).  The step emits
    ``(PairBuffer, row_mask (mb,) bool)`` per micro-batch.

    Multi-tenant mode (DESIGN.md §9): when ``tenant_lookup`` is given, the
    scan inputs gain a ``sq (mb,)`` stream-id lane (xs becomes a 5-tuple),
    the window's ``sids`` lane is threaded into both joins as the
    stream-equality mask, and ``tenant_lookup(sq) → (theta_q, lam_q) |
    None`` supplies the per-row thresholds from the tenant table (return
    ``None`` for uniform tenants).  ``embed_fn`` optionally maps the raw
    per-micro-batch payload (e.g. token ids) to unit vectors *inside* the
    same program — the fused embed→join path.
    """
    kw = cfg.join_kwargs
    ckw = cfg.candidate_kwargs
    multi = tenant_lookup is not None
    if cfg.emit_dense and self_mask is not None:
        raise ValueError("emit_dense oracle path is single-device only")
    if cfg.emit_dense and (multi or embed_fn is not None):
        raise ValueError(
            "the emit_dense oracle path is single-tenant and takes vectors; "
            "multi-tenant / fused-embed runs use the hierarchical path"
        )

    def micro_step(carry, xs):
        state, telem = carry
        if multi:
            q, tq, uq, sq, n_valid = xs
            sq = sq.astype(jnp.int32)
        else:
            q, tq, uq, n_valid = xs
            sq = None
        if embed_fn is not None:
            q = embed_fn(q)
        tq = tq.astype(jnp.float32)
        uq = uq.astype(jnp.int32)
        # join vs the window and within the micro-batch; padded rows carry
        # uid = -1 so the kernel's order mask silences them everywhere
        if cfg.emit_dense:
            # PR-1 oracle: dense (mb, capacity+mb) scores + global top-k
            s_win, it_win, _ = sssj_join_tiles(
                q, state.vecs, tq, state.ts, uq, state.uids, **kw
            )
            s_self, _, _ = sssj_join_tiles(q, q, tq, tq, uq, uq, **kw)
            scores = jnp.concatenate([s_win, s_self], axis=1)
            uw_all = jnp.concatenate([state.uids, uq])
            buf = compact_pairs(scores, uq, uw_all, max_pairs=cfg.max_pairs)
            row_mask = jnp.any(scores > 0.0, axis=1)
            gate_stats = jnp.zeros((3,), jnp.int32)
        else:
            # hierarchical: per-tile level-1 candidates → segmented merge;
            # no dense score matrix exists anywhere on this path
            if multi:
                per_row = tenant_lookup(sq)
                theta_q, lam_q = per_row if per_row is not None else (None, None)
                win_kw = dict(sq=sq, sw=state.sids,
                              theta_q=theta_q, lam_q=lam_q)
                self_kw = dict(sq=sq, sw=sq, theta_q=theta_q, lam_q=lam_q)
            else:
                win_kw = self_kw = {}
            # the window join consults the strip summary (None = ungated);
            # the self-join never does — its strips are this micro-batch,
            # freshly scored either way
            jw = sssj_join_candidates(
                q, state.vecs, tq, state.ts, uq, state.uids,
                summary=state.summary, **ckw, **win_kw
            )
            js = sssj_join_candidates(q, q, tq, tq, uq, uq, **ckw, **self_kw)
            cs = js.cands if self_mask is None else self_mask(js.cands)
            buf = merge_candidates(
                concat_candidates(jw.cands, cs), max_pairs=cfg.max_pairs
            )
            row_mask = jw.row_mask | js.row_mask
            it_win = jw.iters
            gate_stats = (
                jw.gate_stats if jw.gate_stats is not None
                else jnp.zeros((3,), jnp.int32)
            )

        # newest valid arrival — the reference point for live-slot overflow
        lanes = jnp.arange(q.shape[0], dtype=jnp.int32)
        t_max = jnp.max(jnp.where(lanes < n_valid, tq, -jnp.inf))
        if multi:
            new_state = ingest(state, q, tq, uq, n_valid, t_max, sq)
        else:
            new_state = ingest(state, q, tq, uq, n_valid, t_max)
        new_telem = EngineTelemetry(
            chunks=telem.chunks + it_win.sum(),
            tiles=telem.tiles + it_win.size,
            pairs=telem.pairs + buf.n_pairs,
            dropped=telem.dropped + buf.n_dropped,
            dropped_tile=telem.dropped_tile + buf.n_dropped_tile,
            tiles_skipped_time=telem.tiles_skipped_time + gate_stats[0],
            tiles_skipped_l2=telem.tiles_skipped_l2 + gate_stats[1],
            strips_survived=telem.strips_survived + gate_stats[2],
        )
        return (new_state, new_telem), (buf, row_mask)

    return micro_step


def make_batch_step(cfg: EngineConfig):
    """Build the jitted request-batch step (single device).

    Signature: ``(state, telem, qs, tqs, uqs, nvs) → (state, telem, bufs,
    masks)`` with ``qs (n_micro, mb, d)``, ``tqs/uqs (n_micro, mb)``,
    ``nvs (n_micro,)`` valid-row counts, ``bufs`` a :class:`PairBuffer`
    whose leaves are stacked over micro-batches, and ``masks (n_micro, mb)``
    the per-row match masks.  State and telemetry are donated.
    """
    tau = cfg.tau
    quo = cfg.quotas_device()

    def ingest(state, q, tq, uq, n_valid, t_max):
        return push_with_overflow(
            state, q, tq, uq, n_valid, t_max, tau,
            eviction=cfg.eviction, quotas=quo,
            summary_block_w=cfg.block_w, summary_chunk_d=cfg.chunk_d,
        )

    micro_step = make_micro_step(cfg, ingest)

    def batch_step(state, telem, qs, tqs, uqs, nvs):
        (state, telem), (bufs, masks) = jax.lax.scan(
            micro_step, (state, telem), (qs, tqs, uqs, nvs)
        )
        return state, telem, bufs, masks

    return jax.jit(batch_step, donate_argnums=(0, 1))


class StreamEngineBase:
    """Host facade shared by the single-device and sharded engines.

    Subclasses set ``state``, ``telem``, and ``_step`` in ``__init__`` and
    override :meth:`_global_capacity`.  Compacted buffers carry one merged
    segment per micro-batch (the sharded engine merges its shards down to
    one global buffer before they reach the host); ``drain_arrays`` still
    handles multi-segment layouts through the trailing-axis reshape.

    D2H copies run on a single-worker copy thread: ``push`` dispatches the
    device step and enqueues the output buffers; the worker materializes
    them to numpy (double-buffered — device compute of the next push
    overlaps the copy of the previous one); ``drain_*`` only joins on the
    already-copied results.
    """

    def __init__(
        self, cfg: EngineConfig, registry: Optional[MetricsRegistry] = None
    ) -> None:
        # cfg invariants are enforced by EngineConfig.__post_init__
        self.cfg = cfg
        self._next_uid = 0
        # futures of host-materialized (bufs, masks, nvs, nbytes, t_done,
        # fetch_s) records
        self._pending: List[concurrent.futures.Future] = []
        self._copier = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="sssj-drain"
        )
        self.n_items = 0
        # host↔device traffic accounting (what the dense path would have
        # moved vs what the compacted path actually moves)
        self.bytes_to_host = 0
        self.bytes_dense_equiv = 0
        # unified observability surface (DESIGN.md §12): engine counters
        # publish under engine/… at snapshot time; stats() is a
        # compatibility view over the same snapshot
        self.registry = registry if registry is not None else MetricsRegistry()
        self.registry.register_collector(self._publish_metrics)

    def _global_capacity(self) -> int:
        return self.cfg.capacity

    # ------------------------------------------------------------------ #
    def push(self, vecs: np.ndarray, ts: np.ndarray) -> np.ndarray:
        """Feed one request batch; returns the uids assigned to it.

        Does NOT synchronize with the device — call :meth:`drain_pairs` /
        :meth:`drain_arrays` to collect emitted pairs.  A new batch size
        triggers one recompile of the scan.
        """
        b = np.asarray(vecs).shape[0]
        if b == 0:
            return np.empty((0,), np.int32)
        uq, qs, tqs, uqs, nvs = pad_request(
            vecs, ts, self._next_uid, self.cfg.micro_batch
        )
        self._next_uid += b
        self.n_items += b
        self.state, self.telem, bufs, masks = self._step(
            self.state, self.telem, qs, tqs, uqs, nvs
        )
        self._pending.append(self._copier.submit(self._fetch, bufs, masks, nvs))
        # the dense path would have fetched (mb, capacity) + (mb, mb) f32
        # score matrices per micro-batch
        mb = self.cfg.micro_batch
        self.bytes_dense_equiv += qs.shape[0] * 4 * (
            mb * self._global_capacity() + mb * mb
        )
        return uq

    @staticmethod
    def _fetch(bufs: PairBuffer, masks, nvs: np.ndarray):
        """Worker-thread D2H: materialize one push's device outputs.

        Stamps ``t_done`` (monotonic) when the copy lands — the moment
        this batch's pairs become host-visible, which is what
        admission→emission latency measures — plus the copy duration for
        the ``drain`` pipeline span.
        """
        t0 = time.monotonic()
        host = jax.tree.map(np.asarray, bufs)
        masks = np.asarray(masks)
        nbytes = sum(x.nbytes for x in host) + masks.nbytes
        t_done = time.monotonic()
        return host, masks, nvs, nbytes, t_done, t_done - t0

    # ------------------------------------------------------------------ #
    def _observe_emission(self, t_done: float, fetch_s: float) -> None:
        """Per-record drain hook (admission→emission latency attribution
        in the multi-tenant runtime); records arrive in dispatch order."""

    def _drain(self):
        recs = [f.result() for f in self._pending]
        self._pending.clear()
        ua_all, ub_all, sc_all, mk_all = [], [], [], []
        for bufs, masks, nvs, nbytes, t_done, fetch_s in recs:
            self.bytes_to_host += nbytes
            self._observe_emission(t_done, fetch_s)
            n = np.asarray(bufs.n_pairs)
            n = n.reshape(n.shape[0], -1)             # (n_micro, n_segments)
            n_micro, n_seg = n.shape
            width = bufs.uid_a.reshape(n_micro, -1).shape[1] // n_seg
            sel = np.arange(width)[None, None, :] < n[:, :, None]
            # row-major (micro, segment, rank) flatten == stream order
            ua_all.append(bufs.uid_a.reshape(n_micro, n_seg, width)[sel])
            ub_all.append(bufs.uid_b.reshape(n_micro, n_seg, width)[sel])
            sc_all.append(bufs.score.reshape(n_micro, n_seg, width)[sel])
            lanes = np.arange(masks.shape[1])[None, :]
            mk_all.append(masks[lanes < nvs[:, None]])
        if not ua_all:
            z = np.empty((0,), np.int32)
            return z, z.copy(), np.empty((0,), np.float32), np.empty((0,), bool)
        return (
            np.concatenate(ua_all),
            np.concatenate(ub_all),
            np.concatenate(sc_all),
            np.concatenate(mk_all).astype(bool),
        )

    def drain_arrays(
        self, return_masks: bool = False
    ) -> Tuple[np.ndarray, ...]:
        """Collect everything emitted since the last drain.

        Returns ``(uid_a, uid_b, score)`` arrays for every pair (uid_a is
        the newer item).  With ``return_masks=True`` a fourth array rides
        along: a ``(n_items,)`` bool per-row match mask, aligned with the
        uids handed out by the intervening :meth:`push` calls — exact even
        when pair emission overflowed (it derives from level-1 counts,
        DESIGN.md §3).
        """
        ua, ub, sc, mk = self._drain()
        if return_masks:
            return ua, ub, sc, mk
        return ua, ub, sc

    def drain_pairs(self) -> List[Tuple[int, int, float]]:
        """Compatibility drain: list of ``(uid_a, uid_b, score)`` tuples."""
        ua, ub, sc = self.drain_arrays()
        return list(zip(ua.tolist(), ub.tolist(), sc.tolist()))

    def close(self) -> None:
        """Release the drain worker thread; undrained copies are abandoned
        (the worker finishes any copy already in flight, then exits)."""
        self._copier.shutdown(wait=False)

    def __del__(self) -> None:
        try:
            self._copier.shutdown(wait=False)
        except Exception:
            pass

    # ------------------------------------------------------------------ #
    @property
    def overflow(self) -> int:
        """Live ring slots overwritten (window undersized), all shards."""
        return int(np.asarray(self.state.overflow).sum())

    @property
    def pairs_dropped(self) -> int:
        """Total pairs lost to emission capacity at any level (tile_k,
        per-shard, or max_pairs) — the undersized-buffer signal."""
        t = self.telem
        return int(
            np.asarray(t.dropped).sum() + np.asarray(t.dropped_tile).sum()
        )

    @property
    def overflow_by_tenant(self) -> Optional[np.ndarray]:
        """Per-victim-stream live overwrites ``(n_lanes,)``, summed over
        shards; ``None`` when the state carries no stream lanes."""
        lo = self.state.lane_overflow
        if lo is None:
            return None
        arr = np.asarray(lo)
        return arr.reshape(-1, arr.shape[-1]).sum(axis=0)

    def _publish_metrics(self, reg: MetricsRegistry) -> None:
        """Snapshot-time collector: engine counters under ``engine/…``,
        per-victim-stream overflow under ``tenant/<k>/…`` (DESIGN.md §12).
        Device telemetry is summed here exactly as the legacy ``stats()``
        did, so registry and legacy values are the same numbers."""
        t = jax.tree.map(lambda x: int(np.asarray(x).sum()), self.telem)
        c = reg.counter
        c("engine/n_items").set(self.n_items)
        c("engine/chunks_executed").set(t.chunks)
        c("engine/tiles_total").set(t.tiles)
        c("engine/pairs_emitted").set(t.pairs)
        c("engine/pairs_dropped").set(t.dropped + t.dropped_tile)
        c("engine/pairs_dropped_budget").set(t.dropped)
        c("engine/pairs_dropped_tile").set(t.dropped_tile)
        c("engine/window_overflow").set(self.overflow)
        c("engine/bytes_to_host").set(self.bytes_to_host)
        c("engine/bytes_dense_equiv").set(self.bytes_dense_equiv)
        # L2/prefix gate counters (DESIGN.md §13); tiles_total repeats the
        # window-join tile count so skip fractions are self-contained
        c("engine/prune/tiles_total").set(t.tiles)
        c("engine/prune/tiles_skipped_time").set(t.tiles_skipped_time)
        c("engine/prune/tiles_skipped_l2").set(t.tiles_skipped_l2)
        c("engine/prune/strips_survived").set(t.strips_survived)
        by_tenant = self.overflow_by_tenant
        if by_tenant is not None:
            for k, v in enumerate(by_tenant.tolist()):
                c(f"tenant/{k}/window_overflow").set(int(v))

    @staticmethod
    def _legacy_engine_view(snap: dict) -> dict:
        """The pre-registry ``stats()`` key vocabulary, derived from a
        registry snapshot (the compatibility view, DESIGN.md §12)."""
        out = {
            "n_items": snap["engine/n_items"],
            "chunks_executed": snap["engine/chunks_executed"],
            "tiles_total": snap["engine/tiles_total"],
            "pairs_emitted": snap["engine/pairs_emitted"],
            "pairs_dropped": snap["engine/pairs_dropped"],
            "pairs_dropped_budget": snap["engine/pairs_dropped_budget"],
            "pairs_dropped_tile": snap["engine/pairs_dropped_tile"],
            "window_overflow": snap["engine/window_overflow"],
            "bytes_to_host": snap["engine/bytes_to_host"],
            "bytes_dense_equiv": snap["engine/bytes_dense_equiv"],
        }
        by_tenant = []
        while f"tenant/{len(by_tenant)}/window_overflow" in snap:
            by_tenant.append(snap[f"tenant/{len(by_tenant)}/window_overflow"])
        if by_tenant:
            out["window_overflow_by_tenant"] = by_tenant
        return out

    def metrics(self) -> dict:
        """The namespaced registry snapshot (the primary stats surface)."""
        return self.registry.snapshot()

    def stats(self) -> dict:
        return self._legacy_engine_view(self.registry.snapshot())


class StreamEngine(StreamEngineBase):
    """Single-device scan-pipelined engine over one ring window."""

    def __init__(self, cfg: EngineConfig) -> None:
        super().__init__(cfg)
        self.state: WindowState = init_window(
            cfg.capacity, cfg.d, n_lanes=cfg.n_lanes, eviction=cfg.eviction,
            summary_block_w=cfg.block_w if cfg.gate_enabled else None,
            summary_chunk_d=cfg.chunk_d,
        )
        self.telem = init_telemetry()
        self._step = make_batch_step(cfg)
