"""Device-resident streaming join engine: one jit call per request batch.

The previous host driver (:class:`repro.core.blocked.BlockedStreamJoiner`)
re-entered jit once per micro-batch, fetched the dense ``(B, capacity)`` +
``(B, B)`` score matrices to the host, and extracted pairs in a Python
``np.nonzero`` loop — throughput was bounded by PCIe and the GIL, not the
MXU.  The engine restores the paper's invariant that candidate generation,
time filtering, and verification never leave the index's hot loop:

  * the ring-buffer :class:`WindowState` is carried through a single
    ``lax.scan`` over micro-batches (one jit call — and one device
    round-trip of *control*, not data — per request batch, donated state);
  * emission is compacted on device (:mod:`repro.kernels.sssj_join.compact`)
    so only fixed-capacity ``(max_pairs,)`` buffers plus a few scalars ever
    cross to the host — O(pairs) bytes instead of O(B·capacity);
  * the host drain is asynchronous: :meth:`StreamEngine.push` enqueues the
    device buffers and returns without synchronizing; pairs materialize on
    the host only when the caller asks (:meth:`drain_arrays` /
    :meth:`drain_pairs`), so back-to-back pushes pipeline on the device.

Telemetry (pruning iterations, emitted/dropped pair counts, overflow)
accumulates in-carry as device scalars and is summed on the host only at
:meth:`stats` time.

The scan body (:func:`make_micro_step`) and the host facade
(:class:`StreamEngineBase`) are shared with the sharded fan-out
(:mod:`repro.engine.sharded`): the sharded variant differs only in which
rows each device ingests and in emitting self-join pairs on one shard.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.similarity import time_horizon
from ..kernels.sssj_join import PairBuffer, compact_pairs, sssj_join_tiles
from .window import WindowState, init_window, push_with_overflow

__all__ = [
    "EngineConfig",
    "EngineTelemetry",
    "StreamEngine",
    "StreamEngineBase",
    "make_batch_step",
    "make_micro_step",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    theta: float
    lam: float
    capacity: int
    d: int
    micro_batch: int = 128       # scan step size; requests are padded up
    max_pairs: int = 4096        # compacted-emission capacity per micro-batch
    block_q: int = 128
    block_w: int = 128
    chunk_d: int = 128
    use_ref: bool = False        # route joins through the jnp oracle
    interpret: Optional[bool] = None

    @property
    def tau(self) -> float:
        return time_horizon(self.theta, self.lam)

    @property
    def join_kwargs(self) -> dict:
        return dict(
            theta=self.theta, lam=self.lam, block_q=self.block_q,
            block_w=self.block_w, chunk_d=self.chunk_d, use_ref=self.use_ref,
            interpret=self.interpret,
        )


class EngineTelemetry(NamedTuple):
    """Device-resident counters accumulated in the scan carry.

    ``chunks``/``tiles`` count the *window* join only (self-join tiles have
    near-zero time deltas and would dilute the pruning signal) — the same
    accounting the pre-engine driver used, so ``benchmarks/tile_pruning.py``
    numbers stay comparable across versions.
    """

    chunks: jax.Array        # () i32 — d-chunks executed (pruning telemetry)
    tiles: jax.Array         # () i32 — window-join tiles visited
    pairs: jax.Array         # () i32 — pairs emitted (compacted)
    dropped: jax.Array       # () i32 — pairs lost to max_pairs overflow


def init_telemetry() -> EngineTelemetry:
    # distinct buffers: the step donates the whole pytree, and donating one
    # buffer twice is an error
    return EngineTelemetry(*(jnp.zeros((), jnp.int32) for _ in range(4)))


def pad_request(vecs, ts, next_uid: int, micro_batch: int):
    """Host-side request prep shared by both engines: assign uids, pad the
    batch to a micro-batch multiple (pad rows carry ``uid = -1`` so the
    kernel order mask silences them; pad timestamps repeat the last valid
    one), and reshape into scan inputs.

    Returns ``(uq, qs, tqs, uqs, nvs)``: the assigned uids ``(b,)`` plus
    the scan stacks ``(n_micro, mb, ·)`` and valid-row counts ``(n_micro,)``.
    """
    vecs = np.asarray(vecs, np.float32)
    ts = np.asarray(ts, np.float32).reshape(-1)
    b = vecs.shape[0]
    uq = np.arange(next_uid, next_uid + b, dtype=np.int32)
    mb = micro_batch
    n_micro = -(-b // mb)
    pad = n_micro * mb - b
    if pad:
        vecs = np.concatenate([vecs, np.zeros((pad, vecs.shape[1]), np.float32)])
        ts = np.concatenate([ts, np.full(pad, ts[-1], np.float32)])
        uq_in = np.concatenate([uq, np.full(pad, -1, np.int32)])
    else:
        uq_in = uq
    nvs = np.full(n_micro, mb, np.int32)
    nvs[-1] = mb - pad
    return (
        uq,
        jnp.asarray(vecs.reshape(n_micro, mb, -1)),
        jnp.asarray(ts.reshape(n_micro, mb)),
        jnp.asarray(uq_in.reshape(n_micro, mb)),
        jnp.asarray(nvs),
    )


def make_micro_step(
    cfg: EngineConfig,
    ingest: Callable,
    self_mask: Optional[Callable[[jax.Array], jax.Array]] = None,
):
    """Build the scan body shared by the single-device and sharded engines.

    ``ingest(state, q, tq, uq, n_valid, t_max) → new state`` pushes this
    micro-batch (or the shard's slice of it) into the ring with overflow
    accounting; ``self_mask`` optionally zeroes the within-batch scores
    (the sharded engine emits them on one shard only).
    """
    kw = cfg.join_kwargs

    def micro_step(carry, xs):
        state, telem = carry
        q, tq, uq, n_valid = xs
        tq = tq.astype(jnp.float32)
        uq = uq.astype(jnp.int32)
        # join vs the window and within the micro-batch; padded rows carry
        # uid = -1 so the kernel's order mask silences them everywhere
        s_win, it_win, _ = sssj_join_tiles(
            q, state.vecs, tq, state.ts, uq, state.uids, **kw
        )
        s_self, _, _ = sssj_join_tiles(q, q, tq, tq, uq, uq, **kw)
        if self_mask is not None:
            s_self = self_mask(s_self)
        scores = jnp.concatenate([s_win, s_self], axis=1)
        uw_all = jnp.concatenate([state.uids, uq])
        buf = compact_pairs(scores, uq, uw_all, max_pairs=cfg.max_pairs)

        # newest valid arrival — the reference point for live-slot overflow
        lanes = jnp.arange(q.shape[0], dtype=jnp.int32)
        t_max = jnp.max(jnp.where(lanes < n_valid, tq, -jnp.inf))
        new_state = ingest(state, q, tq, uq, n_valid, t_max)
        new_telem = EngineTelemetry(
            chunks=telem.chunks + it_win.sum(),
            tiles=telem.tiles + it_win.size,
            pairs=telem.pairs + buf.n_pairs,
            dropped=telem.dropped + buf.n_dropped,
        )
        return (new_state, new_telem), buf

    return micro_step


def make_batch_step(cfg: EngineConfig):
    """Build the jitted request-batch step (single device).

    Signature: ``(state, telem, qs, tqs, uqs, nvs) → (state, telem, bufs)``
    with ``qs (n_micro, mb, d)``, ``tqs/uqs (n_micro, mb)``, ``nvs
    (n_micro,)`` valid-row counts, and ``bufs`` a :class:`PairBuffer` whose
    leaves are stacked over micro-batches.  State and telemetry are donated.
    """
    tau = cfg.tau

    def ingest(state, q, tq, uq, n_valid, t_max):
        return push_with_overflow(state, q, tq, uq, n_valid, t_max, tau)

    micro_step = make_micro_step(cfg, ingest)

    def batch_step(state, telem, qs, tqs, uqs, nvs):
        (state, telem), bufs = jax.lax.scan(
            micro_step, (state, telem), (qs, tqs, uqs, nvs)
        )
        return state, telem, bufs

    return jax.jit(batch_step, donate_argnums=(0, 1))


class StreamEngineBase:
    """Host facade shared by the single-device and sharded engines.

    Subclasses set ``state``, ``telem``, and ``_step`` in ``__init__`` and
    override :meth:`_global_capacity`.  Compacted buffers may carry one
    segment (single device) or one per shard; ``drain_arrays`` handles both
    through the trailing-axis reshape.
    """

    def __init__(self, cfg: EngineConfig) -> None:
        if cfg.max_pairs < 1:
            raise ValueError("max_pairs must be ≥ 1")
        self.cfg = cfg
        self._next_uid = 0
        self._pending: List[PairBuffer] = []
        self.n_items = 0
        # host↔device traffic accounting (what the dense path would have
        # moved vs what the compacted path actually moves)
        self.bytes_to_host = 0
        self.bytes_dense_equiv = 0

    def _global_capacity(self) -> int:
        return self.cfg.capacity

    # ------------------------------------------------------------------ #
    def push(self, vecs: np.ndarray, ts: np.ndarray) -> np.ndarray:
        """Feed one request batch; returns the uids assigned to it.

        Does NOT synchronize with the device — call :meth:`drain_pairs` /
        :meth:`drain_arrays` to collect emitted pairs.  A new batch size
        triggers one recompile of the scan.
        """
        b = np.asarray(vecs).shape[0]
        if b == 0:
            return np.empty((0,), np.int32)
        uq, qs, tqs, uqs, nvs = pad_request(
            vecs, ts, self._next_uid, self.cfg.micro_batch
        )
        self._next_uid += b
        self.n_items += b
        self.state, self.telem, bufs = self._step(
            self.state, self.telem, qs, tqs, uqs, nvs
        )
        self._pending.append(bufs)
        # the dense path would have fetched (mb, capacity) + (mb, mb) f32
        # score matrices per micro-batch
        mb = self.cfg.micro_batch
        self.bytes_dense_equiv += qs.shape[0] * 4 * (
            mb * self._global_capacity() + mb * mb
        )
        return uq

    # ------------------------------------------------------------------ #
    def drain_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Synchronize and return ``(uid_a, uid_b, score)`` arrays for every
        pair emitted since the last drain (uid_a is the newer item)."""
        mp = self.cfg.max_pairs
        ua_all, ub_all, sc_all = [], [], []
        for bufs in self._pending:
            n = np.asarray(bufs.n_pairs)
            n = n.reshape(n.shape[0], -1)             # (n_micro, n_segments)
            ua = np.asarray(bufs.uid_a).reshape(n.shape[0], -1)
            ub = np.asarray(bufs.uid_b).reshape(n.shape[0], -1)
            sc = np.asarray(bufs.score).reshape(n.shape[0], -1)
            self.bytes_to_host += ua.nbytes + ub.nbytes + sc.nbytes + n.nbytes
            for i in range(n.shape[0]):
                for s in range(n.shape[1]):
                    k = int(n[i, s])
                    ua_all.append(ua[i, s * mp: s * mp + k])
                    ub_all.append(ub[i, s * mp: s * mp + k])
                    sc_all.append(sc[i, s * mp: s * mp + k])
        self._pending.clear()
        if not ua_all:
            z = np.empty((0,), np.int32)
            return z, z.copy(), np.empty((0,), np.float32)
        return (
            np.concatenate(ua_all),
            np.concatenate(ub_all),
            np.concatenate(sc_all),
        )

    def drain_pairs(self) -> List[Tuple[int, int, float]]:
        """Compatibility drain: list of ``(uid_a, uid_b, score)`` tuples."""
        ua, ub, sc = self.drain_arrays()
        return list(zip(ua.tolist(), ub.tolist(), sc.tolist()))

    # ------------------------------------------------------------------ #
    @property
    def overflow(self) -> int:
        """Live ring slots overwritten (window undersized), all shards."""
        return int(np.asarray(self.state.overflow).sum())

    @property
    def pairs_dropped(self) -> int:
        """Pairs lost to ``max_pairs`` emission overflow (undersized buffer)."""
        return int(np.asarray(self.telem.dropped).sum())

    def stats(self) -> dict:
        t = jax.tree.map(lambda x: int(np.asarray(x).sum()), self.telem)
        return {
            "n_items": self.n_items,
            "chunks_executed": t.chunks,
            "tiles_total": t.tiles,
            "pairs_emitted": t.pairs,
            "pairs_dropped": t.dropped,
            "window_overflow": self.overflow,
            "bytes_to_host": self.bytes_to_host,
            "bytes_dense_equiv": self.bytes_dense_equiv,
        }


class StreamEngine(StreamEngineBase):
    """Single-device scan-pipelined engine over one ring window."""

    def __init__(self, cfg: EngineConfig) -> None:
        super().__init__(cfg)
        self.state: WindowState = init_window(cfg.capacity, cfg.d)
        self.telem = init_telemetry()
        self._step = make_batch_step(cfg)
