"""Sharded fan-out: each device owns a ring-buffer shard of the window.

The single-device engine's capacity wall is memory: one ring of
``capacity`` vectors.  Here the window is sharded over the mesh axis the
``"window"`` logical axis resolves to (:data:`repro.distributed.sharding
.DEFAULT_RULES` maps it to ``data``), so global capacity grows linearly
with device count — the inverse of the paper's Table-2 result, where STR's
single-host window was the failure mode.

Schedule per micro-batch (inside one ``shard_map`` + ``lax.scan``, reusing
the engine's shared scan body — :func:`repro.engine.engine.make_micro_step`):

  * queries are **broadcast** (replicated) — every device joins the full
    micro-batch against its own window shard only; no ring permutes, no
    raw-vector traffic between devices after the initial broadcast;
  * within-batch pairs are computed everywhere (inputs are replicated) but
    emitted by shard 0 only, so each pair appears exactly once globally;
  * compaction is **three-level hierarchical** (DESIGN.md §3/§5): kernel
    tiles select ``(tile_k,)`` candidates (level 1, inside the join), each
    device merges its tiles into a ``(shard_k,)`` buffer (level 2, inside
    ``shard_map``), and after the ``out_specs`` gather one more segmented
    merge packs the per-shard buffers into a single global ``(max_pairs,)``
    buffer — so ``max_pairs`` is a **global** budget, not per-shard, and
    host traffic per micro-batch is O(max_pairs) however many shards exist.
    Per-row match masks are OR-reduced over shards the same way;
  * arrivals are dealt round-robin (item *i* lands on shard ``i mod P``),
    so each shard's ring ages uniformly and eviction stays time-ordered
    per shard.

Multi-tenant composition (DESIGN.md §10): with a
:class:`~repro.runtime.tenants.TenantTable`, the scan inputs gain the
``sqs`` stream-id lane, every ring shard carries its slice of the
``sids`` lane (``WindowState.sids``, dealt round-robin with the vectors),
and the per-tenant ``(θ_k, λ_k)`` tables ride the ``shard_map`` in_specs
**replicated** — each shard looks its query rows' parameters up locally,
and because queries are replicated, every shard derives the *same*
unpadded ``(min θ, min λ)`` pruning scalars, so the bounds stay admissible
shard-for-shard (ops.py contract).  The stream-equality mask is folded
into the join on every shard by the level-1 impls themselves; nothing
about the three-level merge or the global ``max_pairs`` budget changes.

Every drop stays attributed to its level: ``tile_k`` overflow accumulates
in-scan (``dropped_tile``), ``shard_k`` overflow accumulates in-scan
(``dropped``), and global-merge losses accumulate after the gather in a
**dedicated telemetry lane** (lane ``n_shards``; the ``pairs`` counter is
corrected down there too), so ``pairs_emitted`` always equals what the
drain actually delivers while lanes ``0..n_shards-1`` stay honest
per-shard counters (:func:`shard_stats`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import AxisRules, DEFAULT_RULES, shard_map
from ..kernels.sssj_join import PairBuffer, PairCandidates, merge_candidates
from ..kernels.sssj_join.gate import StripSummary, init_strip_summary
from ..obs import merge_disjoint, publish_flat
from .engine import (
    EngineConfig,
    EngineTelemetry,
    StreamEngineBase,
    init_telemetry,
    make_micro_step,
)
from .window import WindowState, init_window, push_with_overflow

__all__ = [
    "ShardedStreamEngine",
    "init_sharded_window",
    "make_sharded_batch_step",
    "shard_metrics",
    "shard_stats",
    "shard_view",
    "window_axis",
]


def window_axis(mesh: Mesh, rules: AxisRules = DEFAULT_RULES) -> str:
    """Mesh axis the logical ``"window"`` axis resolves to under ``rules``."""
    axes = rules.lookup("window")
    if isinstance(axes, str):
        axes = (axes,)
    for a in axes or ():
        if a in mesh.axis_names:
            return a
    raise ValueError(
        f"no mesh axis for logical 'window' (rules {axes!r}, mesh {mesh.axis_names})"
    )


def init_sharded_window(
    cfg: EngineConfig, mesh: Mesh, axis: str, n_lanes: Optional[int] = None
) -> WindowState:
    """Global window of ``cfg.capacity`` per-shard slots × axis size.

    The ``sids`` stream-id lane is always materialized (sharded like
    ``uids``) so the same state pytree serves both the single-tenant
    engine and the multi-tenant runtime's sharded path.  ``n_lanes``
    materializes the per-stream policy lanes (DESIGN.md §11) as
    ``(n_shards, n_lanes)`` replicated-in-lane arrays: each shard owns
    its row — quota sub-rings (and their cursors) are **shard-local**.
    """
    n = mesh.shape[axis]
    if n_lanes is None:
        n_lanes = cfg.n_lanes
    state = init_window(cfg.capacity * n, cfg.d)
    shard = NamedSharding(mesh, P(axis))
    lane_shard = NamedSharding(mesh, P(axis, None))

    def lanes():
        # distinct buffers — the step donates the whole pytree
        return (
            None if n_lanes is None
            else jax.device_put(jnp.zeros((n, n_lanes), jnp.int32), lane_shard)
        )

    def summary():
        if not cfg.gate_enabled:
            return None
        # per-shard summaries must be built at per-shard geometry: a
        # ragged per-shard capacity (capacity % block_w != 0) pads INSIDE
        # each shard, which a global summarize over capacity·n slots would
        # mis-align.  Strip rows concatenate along the shard axis exactly
        # like the ring slots they summarize.
        s1 = init_strip_summary(
            cfg.capacity, cfg.d, block_w=cfg.block_w, chunk_d=cfg.chunk_d
        )
        return jax.tree.map(
            lambda x: jax.device_put(
                jnp.tile(x, (n,) + (1,) * (x.ndim - 1)),
                lane_shard if x.ndim > 1 else shard,
            ),
            s1,
        )

    return WindowState(
        vecs=jax.device_put(state.vecs, NamedSharding(mesh, P(axis, None))),
        ts=jax.device_put(state.ts, shard),
        uids=jax.device_put(state.uids, shard),
        cursor=jax.device_put(jnp.zeros((n,), jnp.int32), shard),
        overflow=jax.device_put(jnp.zeros((n,), jnp.int32), shard),
        sids=jax.device_put(state.sids, shard),
        lane_cursor=lanes() if cfg.eviction == "quota" else None,
        lane_overflow=lanes(),
        summary=summary(),
    )


def make_sharded_batch_step(cfg: EngineConfig, mesh: Mesh, axis: str, table=None):
    """Jitted shard_map step with the same signature as
    :func:`repro.engine.engine.make_batch_step`: per-shard buffers are
    merged into one global ``(max_pairs,)`` buffer per micro-batch and
    masks are OR-reduced over shards before anything reaches the host.

    With a :class:`~repro.runtime.tenants.TenantTable` the signature
    mirrors :func:`repro.runtime.runtime.make_tenant_batch_step` instead —
    ``(state, telem, qs, tqs, uqs, sqs, nvs)`` — and the step becomes
    stream-tagged: the ``sqs`` lane is dealt into each shard's ``sids``
    ring lane, the stream-equality mask rides the level-1 join on every
    shard, and per-query-row ``(theta_q, lam_q)`` are looked up inside the
    ``shard_map`` from the table's device arrays (broadcast replicated
    through the in_specs).
    """

    if cfg.emit_dense:
        raise ValueError(
            "emit_dense is the single-device test oracle; the sharded engine "
            "runs the hierarchical path only"
        )
    p = mesh.shape[axis]
    if cfg.micro_batch % p != 0:
        raise ValueError(f"micro_batch {cfg.micro_batch} not divisible by {p} shards")
    multi = table is not None
    quota = cfg.eviction == "quota"
    lanes = multi or cfg.n_lanes is not None
    tau = table.tau_max if multi else cfg.tau
    per_row = multi and not table.is_uniform
    bl = cfg.micro_batch // p         # arrivals per shard per micro-batch
    shard_k = cfg.shard_k or cfg.max_pairs
    # level-2 (per-shard) merge capacity: the in-scan micro step merges this
    # shard's tiles into a (shard_k,) buffer; the global budget is applied
    # after the gather
    local_cfg = dataclasses.replace(cfg, max_pairs=shard_k)

    def local_core(state, telem, xs, th_t, lm_t, quo_t):
        me = jax.lax.axis_index(axis)

        def ingest(st, q, tq, uq, n_valid, t_max, sq=None):
            # round-robin deal: this shard ingests items me, me+p, me+2p, …
            idx = me + p * jnp.arange(bl, dtype=jnp.int32)
            n_valid_l = jnp.sum((idx < n_valid).astype(jnp.int32))
            return push_with_overflow(
                st, q[idx], tq[idx], uq[idx], n_valid_l, t_max, tau,
                sq=None if sq is None else sq[idx],
                eviction=cfg.eviction, quotas=quo_t,
                summary_block_w=cfg.block_w, summary_chunk_d=cfg.chunk_d,
            )

        # replicated inputs ⇒ every shard computes the same self candidates;
        # only shard 0 keeps them so each pair appears once globally (counts
        # are zeroed, not dropped — suppression is not an overflow).  Row
        # masks stay unmasked: they are identical on every shard and OR'd.
        def self_mask(c: PairCandidates) -> PairCandidates:
            keep = (me == 0).astype(jnp.int32)
            return c._replace(kept=c.kept * keep, emitted=c.emitted * keep)

        lookup = None
        if multi:
            def lookup(sq):
                # replicated queries ⇒ identical per-row lanes (and identical
                # unpadded min-θ/min-λ pruning scalars) on every shard
                if not per_row:
                    return None
                return table.lookup_rows(th_t, lm_t, sq)

        micro = make_micro_step(
            local_cfg, ingest, self_mask=self_mask, tenant_lookup=lookup
        )

        # per-shard scalars travel as (1,) slices of the P(axis) arrays
        # (and the policy lanes as (1, n_lanes) rows)
        def lane0(x):
            return None if x is None else x[0]

        sub = state._replace(
            cursor=state.cursor[0], overflow=state.overflow[0],
            lane_cursor=lane0(state.lane_cursor),
            lane_overflow=lane0(state.lane_overflow),
        )
        tl = jax.tree.map(lambda x: x[0], telem)
        (sub, tl), (bufs, masks) = jax.lax.scan(micro, (sub, tl), xs)
        state = sub._replace(
            cursor=sub.cursor[None], overflow=sub.overflow[None],
            lane_cursor=None if sub.lane_cursor is None
            else sub.lane_cursor[None],
            lane_overflow=None if sub.lane_overflow is None
            else sub.lane_overflow[None],
        )
        telem = jax.tree.map(lambda x: x[None], tl)
        # scalar leaves come out of the scan as (n_micro,); give them a
        # trailing axis so out_specs can concatenate shards along it, and
        # masks a middle axis so shards gather side by side
        bufs = bufs._replace(
            n_pairs=bufs.n_pairs[:, None],
            n_dropped=bufs.n_dropped[:, None],
            n_dropped_tile=bufs.n_dropped_tile[:, None],
        )
        return state, telem, bufs, masks[:, None, :]

    # replicated broadcast args: query lanes, then the optional device
    # tables — tenant (θ, λ) and, under quota eviction, the per-shard
    # quota table (in_specs P() like the tenant tables, DESIGN.md §11) —
    # then the valid-row counts
    def local_batch(state, telem, *rest):
        if multi:
            qs, tqs, uqs, sqs, th_t, lm_t, *rest = rest
        else:
            qs, tqs, uqs, *rest = rest
            sqs = th_t = lm_t = None
        quo_t, (nvs,) = (rest[0], rest[1:]) if quota else (None, rest)
        xs = (
            (qs, tqs, uqs, sqs, nvs) if multi else (qs, tqs, uqs, nvs)
        )
        return local_core(state, telem, xs, th_t, lm_t, quo_t)

    n_bcast = 4 + (3 if multi else 0) + (1 if quota else 0)

    state_specs = WindowState(
        vecs=P(axis, None), ts=P(axis), uids=P(axis),
        cursor=P(axis), overflow=P(axis), sids=P(axis),
        lane_cursor=P(axis, None) if (lanes and quota) else None,
        lane_overflow=P(axis, None) if lanes else None,
        # strip summaries shard along their strip axis, like the ring
        # slots they summarize (each shard gates against its own window)
        summary=StripSummary(
            vmax=P(axis, None), cnorm=P(axis, None),
            tmin=P(axis), tmax=P(axis), umax=P(axis),
        ) if cfg.gate_enabled else None,
    )
    telem_specs = EngineTelemetry(*(P(axis) for _ in EngineTelemetry._fields))
    buf_specs = PairBuffer(
        uid_a=P(None, axis), uid_b=P(None, axis), score=P(None, axis),
        n_pairs=P(None, axis), n_dropped=P(None, axis),
        n_dropped_tile=P(None, axis),
    )
    fn = shard_map(
        local_batch,
        mesh=mesh,
        in_specs=(state_specs, telem_specs) + (P(),) * n_bcast,
        out_specs=(state_specs, telem_specs, buf_specs, P(None, axis, None)),
        check_vma=False,
    )

    def shard_merge(ua, ub, sc, kept):
        """Level 3: gathered per-shard buffers → one global budget."""
        cands = PairCandidates(
            uid_a=ua.reshape(p, shard_k),
            uid_b=ub.reshape(p, shard_k),
            score=sc.reshape(p, shard_k),
            kept=kept,
            emitted=kept,   # shard-level losses were already counted in-scan
        )
        return merge_candidates(cands, max_pairs=cfg.max_pairs)

    def finish(state, tout, extra, bufs, masks):
        gbufs = jax.vmap(shard_merge)(
            bufs.uid_a, bufs.uid_b, bufs.score, bufs.n_pairs
        )
        # the in-scan `pairs` counter summed per-shard survivors; pairs that
        # just fell to the global budget move to `dropped`.  The correction
        # lives in the dedicated lane n (not any shard's lane), so per-shard
        # counters stay honest while the lane sums keep the global
        # invariant pairs_emitted == what the drain delivers
        merge_drops = jnp.sum(gbufs.n_dropped)
        extra = extra._replace(
            pairs=extra.pairs.at[0].add(-merge_drops),
            dropped=extra.dropped.at[0].add(merge_drops),
        )
        telem = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), tout, extra
        )
        return state, telem, gbufs, jnp.any(masks, axis=1)

    def split_lanes(telem):
        # lanes 0..p-1 ride the shard_map (one per shard); lane p carries
        # the global-merge correction and stays on the host side of it
        tin = jax.tree.map(lambda x: x[:p], telem)
        extra = jax.tree.map(lambda x: x[p:], telem)
        return tin, extra

    quo_tail = (cfg.quotas_device(),) if quota else ()
    if multi:
        th_d, lm_d = table.device_tables

        def batch_step(state, telem, qs, tqs, uqs, sqs, nvs):
            tin, extra = split_lanes(telem)
            state, tout, bufs, masks = fn(
                state, tin, qs, tqs, uqs, sqs, th_d, lm_d, *quo_tail, nvs
            )
            return finish(state, tout, extra, bufs, masks)
    else:
        def batch_step(state, telem, qs, tqs, uqs, nvs):
            tin, extra = split_lanes(telem)
            state, tout, bufs, masks = fn(
                state, tin, qs, tqs, uqs, *quo_tail, nvs
            )
            return finish(state, tout, extra, bufs, masks)

    return jax.jit(batch_step, donate_argnums=(0,))


_SHARD_FIELDS = (
    "live_slots", "cursor", "window_overflow",
    "pairs_emitted", "pairs_dropped_budget", "pairs_dropped_tile",
    "tiles_skipped_time", "tiles_skipped_l2", "strips_survived",
)


def shard_metrics(
    state: WindowState, telem: EngineTelemetry, n_shards: int
) -> dict:
    """Per-shard liveness and drop counters as a flat namespaced dict
    (``engine/shard/<i>/…``, DESIGN.md §12) — the registry form; the
    nested legacy view (:func:`shard_stats`) is derived from it, so both
    surfaces are the same numbers by construction.

    Telemetry lanes ``0..n_shards-1`` are the in-scan per-shard counters;
    lane ``n_shards`` holds the global-merge correction (see
    :func:`make_sharded_batch_step`), surfaced as
    ``pairs_dropped_global`` rather than mis-charged to any shard — so
    per-shard ``pairs_emitted`` counts that shard's merge survivors
    *before* the global budget and is never negative."""
    n = n_shards
    uids = np.asarray(state.uids).reshape(n, -1)
    pairs = np.asarray(telem.pairs).reshape(-1)
    dropped = np.asarray(telem.dropped).reshape(-1)
    dropped_tile = np.asarray(telem.dropped_tile).reshape(-1)
    lanes = {
        "live_slots": (uids >= 0).sum(axis=1),
        "cursor": np.asarray(state.cursor).reshape(-1),
        "window_overflow": np.asarray(state.overflow).reshape(-1),
        "pairs_emitted": pairs[:n],
        "pairs_dropped_budget": dropped[:n],
        "pairs_dropped_tile": dropped_tile[:n],
        # per-shard gate lanes: lane p (the global-merge correction lane)
        # never accumulates gate counters, so [:n] loses nothing
        "tiles_skipped_time": np.asarray(telem.tiles_skipped_time).reshape(-1)[:n],
        "tiles_skipped_l2": np.asarray(telem.tiles_skipped_l2).reshape(-1)[:n],
        "strips_survived": np.asarray(telem.strips_survived).reshape(-1)[:n],
    }
    out = {
        "engine/n_shards": n,
        "engine/pairs_dropped_global": int(dropped[n:].sum()),
    }
    for i in range(n):
        for f in _SHARD_FIELDS:
            out[f"engine/shard/{i}/{f}"] = int(lanes[f][i])
    return out


def shard_view(flat: dict) -> dict:
    """The nested legacy per-shard stats vocabulary, rebuilt from a flat
    metrics dict / registry snapshot containing ``engine/shard/<i>/…``."""
    n = int(flat["engine/n_shards"])
    return {
        "n_shards": n,
        "pairs_dropped_global": flat["engine/pairs_dropped_global"],
        "shards": {
            f: [flat[f"engine/shard/{i}/{f}"] for i in range(n)]
            for f in _SHARD_FIELDS
        },
    }


def shard_stats(state: WindowState, telem: EngineTelemetry, n_shards: int) -> dict:
    """Nested per-shard stats (the legacy surface) — a view over
    :func:`shard_metrics`."""
    return shard_view(shard_metrics(state, telem, n_shards))


class ShardedStreamEngine(StreamEngineBase):
    """Host facade mirroring :class:`StreamEngine` over a device mesh.

    ``cfg.capacity`` is the *per-shard* ring size; the global window holds
    ``capacity × n_shards`` items.  ``cfg.max_pairs`` is the **global**
    emission budget per micro-batch (the hierarchical merge packs shard
    buffers down to it), and ``cfg.shard_k`` bounds what a single shard may
    contribute (default: ``max_pairs``).
    """

    def __init__(
        self,
        cfg: EngineConfig,
        mesh: Mesh,
        rules: AxisRules = DEFAULT_RULES,
        axis: Optional[str] = None,
    ) -> None:
        super().__init__(cfg)
        self.mesh = mesh
        self.axis = axis or window_axis(mesh, rules)
        self.n_shards = mesh.shape[self.axis]
        self.state = init_sharded_window(cfg, mesh, self.axis)
        # lanes 0..n-1 per shard + lane n for the global-merge correction
        n = self.n_shards + 1
        self.telem = jax.tree.map(
            lambda x: jnp.zeros((n,), x.dtype), init_telemetry()
        )
        self._step = make_sharded_batch_step(cfg, mesh, self.axis)

    def _global_capacity(self) -> int:
        return self.cfg.capacity * self.n_shards

    def _publish_metrics(self, reg) -> None:
        super()._publish_metrics(reg)
        publish_flat(
            reg, shard_metrics(self.state, self.telem, self.n_shards)
        )

    def stats(self) -> dict:
        snap = self.registry.snapshot()
        return merge_disjoint(self._legacy_engine_view(snap), shard_view(snap))
