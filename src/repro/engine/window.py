"""Policy-driven ring-buffer window: the device-resident time-filtered index.

The paper's circular-buffer posting lists (§6.2) become one fixed-capacity
device array of recent vectors, and the paper equates "oldest" with
"evictable" — an assumption the multi-tenant runtime breaks: one bursty
tenant can overwrite a slow tenant's still-live slots (DESIGN.md §11).
Eviction is therefore a first-class **write-slot policy**, not an accident
of the ring cursor.  :func:`select_write_slots` is a pure, scan-carryable
function from ``(state, micro-batch)`` to per-row destination slots; three
on-device policies exist:

  * ``"oldest"`` — today's behavior, the default: slots advance cyclically
    from the cursor, so overwrite evicts the oldest item.  Bit-identical
    to the pre-policy ring (same slots, same cursor, same counters).
  * ``"dead"``   — prefer *dead* slots (empty, or expired relative to the
    newest arrival's τ-horizon) before any live one, both in cyclic cursor
    order.  On a fully-live ring this degrades exactly to ``"oldest"``;
    when the ring is sized for the live set rather than the arrival rate,
    it clamps live-slot overflow to the true excess ``n_valid − n_dead``.
  * ``"quota"``  — weighted static partition of the ring into per-tenant
    sub-rings: slot range ``[offset_k, offset_k + quota_k)`` belongs to
    stream ``k`` and has its own cursor lane (``WindowState.lane_cursor``),
    so a bursty tenant can only ever overwrite its *own* slots.

Live-slot overwrites are counted globally (``overflow``) and — whenever
the state carries lanes — per *victim* stream (``lane_overflow``: the
tenant whose live item was lost), which is what
``MultiTenantRuntime.stats()["window_overflow_by_tenant"]`` surfaces.

These primitives are shared by every layer that owns a ring: the
single-device :class:`~repro.engine.engine.StreamEngine` carries a
:class:`WindowState` through its ``lax.scan``, the sharded engine gives
each device its own ring shard (quota sub-rings stay shard-local), and
:mod:`repro.core.blocked` / :mod:`repro.core.distributed` push through
:func:`push_with_overflow` so every write path counts overwrites.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.sssj_join.gate import (
    StripSummary,
    init_strip_summary,
    refresh_strip_summary,
)

__all__ = [
    "EVICTION_POLICIES",
    "WindowState",
    "init_window",
    "push_with_overflow",
    "quota_partition",
    "select_write_slots",
]

_EMPTY_T = jnp.float32(3.0e30)

EVICTION_POLICIES = ("oldest", "dead", "quota")


class WindowState(NamedTuple):
    """Ring buffer of recent stream items (a pytree).

    ``sids`` is the stream-id lane of the multi-tenant runtime
    (DESIGN.md §9): each slot remembers which logical stream its item
    belongs to, so the join can mask cross-stream pairs on device.

    ``lane_cursor``/``lane_overflow`` are the per-stream lanes of the
    policy layer (DESIGN.md §11): ``lane_cursor[k]`` is stream *k*'s
    write cursor inside its quota sub-ring (``"quota"`` eviction only),
    and ``lane_overflow[k]`` counts live items of stream *k* that were
    overwritten — attribution is to the **victim**, so a slow tenant can
    see who lost data, under any policy.  All three trail and default to
    ``None`` so legacy constructions (and pytrees that never multiplex
    streams, e.g. ``core/distributed.py``) stay valid — ``None`` is
    simply an absent pytree leaf.
    """

    vecs: jax.Array    # (capacity, d) f32
    ts: jax.Array      # (capacity,) f32; empty slots hold +3e30
    uids: jax.Array    # (capacity,) i32; empty slots hold -1
    cursor: jax.Array  # () i32 — next write slot (cyclic policies)
    overflow: jax.Array  # () i32 — live items overwritten (window undersized)
    sids: Optional[jax.Array] = None  # (capacity,) i32 stream ids; -1 = empty
    lane_cursor: Optional[jax.Array] = None    # (n_lanes,) i32 sub-ring cursors
    lane_overflow: Optional[jax.Array] = None  # (n_lanes,) i32 per-victim-stream
    summary: Optional[StripSummary] = None  # per-strip L2/prefix index
    #   aggregates (DESIGN.md §13); maintained by push_with_overflow and
    #   consumed by the join's pre-launch gate.  Trails with default None
    #   like the lanes, so legacy constructions stay valid.


def init_window(
    capacity: int,
    d: int,
    dtype=jnp.float32,
    n_lanes: Optional[int] = None,
    eviction: str = "oldest",
    summary_block_w: Optional[int] = None,
    summary_chunk_d: int = 128,
) -> WindowState:
    """Empty window.  ``n_lanes`` materializes the per-stream overflow lane
    (and, under ``eviction="quota"``, the per-stream cursor lane);
    ``summary_block_w`` materializes the per-strip L2/prefix summary at
    that strip granularity (pass the join's ``block_w`` so gate strips
    line up with kernel tiles)."""
    if eviction not in EVICTION_POLICIES:
        raise ValueError(
            f"eviction must be one of {EVICTION_POLICIES}, got {eviction!r}"
        )
    # distinct lane buffers: steps donate the whole pytree, and donating
    # one buffer twice is an error
    def lanes():
        return None if n_lanes is None else jnp.zeros((n_lanes,), jnp.int32)

    return WindowState(
        vecs=jnp.zeros((capacity, d), dtype),
        ts=jnp.full((capacity,), _EMPTY_T, jnp.float32),
        uids=jnp.full((capacity,), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
        sids=jnp.full((capacity,), -1, jnp.int32),
        lane_cursor=lanes() if eviction == "quota" else None,
        lane_overflow=lanes(),
        summary=None if summary_block_w is None else init_strip_summary(
            capacity, d, block_w=summary_block_w, chunk_d=summary_chunk_d
        ),
    )


def quota_partition(capacity: int, weights: Sequence[float]) -> Tuple[int, ...]:
    """Integer slot quotas from relative weights: ``quota_k ∝ weight_k``,
    every stream gets ≥ 1 slot, and the quotas sum exactly to ``capacity``
    (largest-remainder rounding)."""
    w = np.asarray(weights, np.float64).reshape(-1)
    k = w.size
    if k == 0:
        raise ValueError("quota_partition needs at least one weight")
    if np.any(w <= 0):
        raise ValueError(f"quota weights must be positive, got {w.tolist()}")
    if capacity < k:
        raise ValueError(f"capacity {capacity} < {k} streams: no slots to split")
    raw = capacity * w / w.sum()
    quotas = np.maximum(1, np.floor(raw).astype(np.int64))
    # distribute the remainder by largest fractional part; a negative
    # remainder (floors forced up to 1) shrinks the largest quotas instead
    order = np.argsort(-(raw - np.floor(raw)), kind="stable")
    rem = capacity - int(quotas.sum())
    i = 0
    while rem > 0:
        quotas[order[i % k]] += 1
        rem -= 1
        i += 1
    while rem < 0:
        j = int(np.argmax(quotas))
        if quotas[j] <= 1:
            raise ValueError(
                f"cannot partition capacity {capacity} over {k} streams"
            )
        quotas[j] -= 1
        rem += 1
    return tuple(int(q) for q in quotas)


def _sid_rows(sq: Optional[jax.Array], b: int) -> jax.Array:
    return jnp.zeros((b,), jnp.int32) if sq is None else sq.astype(jnp.int32)


# --------------------------------------------------------------------- #
# write-slot selection: the policy layer
# --------------------------------------------------------------------- #
def select_write_slots(
    state: WindowState,
    b: int,
    n_valid: jax.Array,
    t_max: jax.Array,
    tau: float,
    sq: Optional[jax.Array] = None,
    eviction: str = "oldest",
    quotas: Optional[jax.Array] = None,
):
    """Pure, scan-carryable write-slot selection for one micro-batch.

    Returns ``(dest, new_cursor, new_lane_cursor, self_evicted)``:
    ``dest (b,) i32`` is each row's slot with ``capacity`` as the
    out-of-bounds drop sentinel (scan padding, and quota rows whose slot a
    later same-batch row reclaims); ``self_evicted (b,) bool`` marks those
    reclaimed rows — arrivals evicted before ever being written, which the
    caller must count as live-slot overflow attributed to the row's own
    stream.  No two rows of a micro-batch ever select the same slot.

    Slot selection never affects *join* results (the join masks by uid and
    stream, not by slot); it decides only which item a wrapped ring
    evicts.  ``"oldest"``/``"dead"`` advance the shared cursor and are
    split-invariant across micro-batch boundaries (``"dead"`` whenever the
    writes land on dead slots — the non-overflow regime); ``"quota"``
    advances only the per-stream cursor lanes.
    """
    cap = state.ts.shape[0]
    n_valid = jnp.asarray(n_valid, jnp.int32)
    lanes = jnp.arange(b, dtype=jnp.int32)
    valid = lanes < n_valid
    no_evict = jnp.zeros((b,), bool)
    if b == 0:
        return lanes, state.cursor, state.lane_cursor, no_evict

    if eviction == "oldest":
        pos = (state.cursor + lanes) % cap
        dest = jnp.where(valid, pos, cap).astype(jnp.int32)
        new_cursor = (state.cursor + n_valid) % cap
        return dest, new_cursor, state.lane_cursor, no_evict

    if eviction == "dead":
        # dead = empty, or expired relative to the newest arrival's horizon
        dead = (state.uids < 0) | (t_max - state.ts > tau)
        rolled = jnp.roll(dead, -state.cursor)          # cyclic from cursor
        cum_dead = jnp.cumsum(rolled.astype(jnp.int32))
        cum_live = jnp.cumsum(jnp.logical_not(rolled).astype(jnp.int32))
        n_dead = cum_dead[-1]
        # row i → (i+1)-th dead slot in cursor order; overflow rows → the
        # (i−n_dead+1)-th live slot (cursor order ≈ oldest-first).  Both are
        # binary searches over a monotone count vector — a gather, no sort.
        dead_idx = jnp.searchsorted(cum_dead, lanes + 1).astype(jnp.int32)
        live_idx = jnp.searchsorted(cum_live, lanes - n_dead + 1).astype(jnp.int32)
        rolled_pos = jnp.where(lanes < n_dead, dead_idx, live_idx)
        pos = (rolled_pos + state.cursor) % cap
        dest = jnp.where(valid, pos, cap).astype(jnp.int32)
        last = rolled_pos[jnp.maximum(n_valid.astype(jnp.int32) - 1, 0)]
        new_cursor = jnp.where(
            n_valid > 0, (state.cursor + last + 1) % cap, state.cursor
        )
        return dest, new_cursor, state.lane_cursor, no_evict

    if eviction == "quota":
        if quotas is None or state.lane_cursor is None:
            raise ValueError(
                "quota eviction needs a quota table and a lane_cursor state "
                "(init_window(..., n_lanes=K, eviction='quota'))"
            )
        k_tab = quotas.shape[0]
        offs = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(quotas)[:-1].astype(jnp.int32)]
        )
        # clip BEFORE ranking: an out-of-range sid aliases to its clipped
        # lane everywhere (rank, cursor, destination), so two rows can
        # never agree on a slot while disagreeing on a rank
        k = jnp.clip(_sid_rows(sq, b), 0, k_tab - 1)
        qk = quotas[k]                                   # (b,) sub-ring sizes
        base = state.lane_cursor[k]
        # rank among this stream's valid rows of the micro-batch: positions
        # inside the sub-ring are base + rank (mod quota), so rows of one
        # stream fill its sub-ring in admission order
        same = (k[:, None] == k[None, :]) & valid[:, None] & valid[None, :]
        rank = jnp.sum(jnp.tril(same, -1), axis=1)
        count = jnp.sum(same, axis=1)                    # incl. the row itself
        pos = offs[k] + (base + rank) % qk
        # if one stream wraps its own sub-ring within a single micro-batch,
        # the newest writer of each slot wins; earlier rows are evicted
        # before ever being written (self_evicted — counted by the caller)
        survives = rank >= count - qk
        dest = jnp.where(valid & survives, pos, cap).astype(jnp.int32)
        counts_k = jnp.zeros((k_tab,), jnp.int32).at[k].add(
            valid.astype(jnp.int32)
        )
        new_lane_cursor = (state.lane_cursor + counts_k) % quotas
        return dest, state.cursor, new_lane_cursor, valid & ~survives

    raise ValueError(
        f"eviction must be one of {EVICTION_POLICIES}, got {eviction!r}"
    )


def _apply_writes(
    state: WindowState,
    dest: jax.Array,
    q: jax.Array,
    tq: jax.Array,
    uq: jax.Array,
    sq: Optional[jax.Array],
    new_cursor: jax.Array,
    new_lane_cursor: Optional[jax.Array],
) -> WindowState:
    """Scatter one micro-batch to its selected slots (``dest == capacity``
    rows are routed out of bounds and dropped)."""
    b = q.shape[0]
    return state._replace(
        vecs=state.vecs.at[dest].set(q.astype(state.vecs.dtype), mode="drop"),
        ts=state.ts.at[dest].set(tq.astype(jnp.float32), mode="drop"),
        uids=state.uids.at[dest].set(uq.astype(jnp.int32), mode="drop"),
        cursor=new_cursor,
        sids=None if state.sids is None
        else state.sids.at[dest].set(_sid_rows(sq, b), mode="drop"),
        lane_cursor=new_lane_cursor,
    )


def push_with_overflow(
    state: WindowState,
    q: jax.Array,
    tq: jax.Array,
    uq: jax.Array,
    n_valid: jax.Array,
    t_max: jax.Array,
    tau: float,
    sq: Optional[jax.Array] = None,
    eviction: str = "oldest",
    quotas: Optional[jax.Array] = None,
    summary_block_w: Optional[int] = None,
    summary_chunk_d: Optional[int] = None,
) -> WindowState:
    """Policy-driven masked push that also counts live-slot overwrites.

    A slot is *live* if it holds a real item (uid ≥ 0) still within the
    horizon ``tau`` of the newest arrival ``t_max``; overwriting one means
    the window is undersized for this policy and emission becomes
    best-effort, so the ``overflow`` counter records it — and, when the
    state carries lanes, ``lane_overflow`` charges it to the **victim**'s
    stream (under ``"quota"`` the victim is always the writer's own
    stream, which is the isolation guarantee).

    When the state carries a :class:`StripSummary`, the write also
    refreshes the summaries of every strip it touched — keyed off the
    selected destination slots, so the maintenance is policy-agnostic
    (an eviction under any policy updates the victim strip's aggregates).
    ``summary_block_w``/``summary_chunk_d`` must then be the values the
    summary was built with.
    """
    cap = state.ts.shape[0]
    b = q.shape[0]
    dest, new_cursor, new_lane, self_evicted = select_write_slots(
        state, b, n_valid, t_max, tau, sq=sq, eviction=eviction, quotas=quotas,
    )
    read = jnp.minimum(dest, cap - 1)
    live = (
        (dest < cap)
        & (state.uids[read] >= 0)
        & (t_max - state.ts[read] <= tau)
    )
    lost = live | self_evicted
    new_state = _apply_writes(
        state, dest, q, tq, uq, sq, new_cursor, new_lane
    )
    if state.summary is not None:
        if summary_block_w is None or summary_chunk_d is None:
            raise ValueError(
                "state carries a strip summary: push_with_overflow needs "
                "summary_block_w/summary_chunk_d to refresh it"
            )
        new_state = new_state._replace(
            summary=refresh_strip_summary(
                state.summary,
                new_state.vecs, new_state.ts, new_state.uids, dest,
                block_w=summary_block_w, chunk_d=summary_chunk_d,
            )
        )
    lane_overflow = state.lane_overflow
    if lane_overflow is not None:
        n_lanes = lane_overflow.shape[0]
        victim_sid = state.sids[read] if state.sids is not None else jnp.zeros(
            (b,), jnp.int32
        )
        # a self-evicted arrival is its own victim; clip pads defensively
        victim = jnp.clip(
            jnp.where(live, victim_sid, _sid_rows(sq, b)), 0, n_lanes - 1
        )
        lane_overflow = lane_overflow.at[victim].add(lost.astype(jnp.int32))
    return new_state._replace(
        overflow=state.overflow + jnp.sum(lost.astype(jnp.int32)),
        lane_overflow=lane_overflow,
    )
