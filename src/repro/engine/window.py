"""Ring-buffer window state: the device-resident time-filtered index.

The paper's circular-buffer posting lists (§6.2) become one fixed-capacity
device array of the most recent vectors.  Eviction is implicit — ring
overwrite drops the oldest items, which the time filter justifies as long
as ``capacity ≥ arrival_rate · τ`` — and an overflow counter records when
live items (still within the horizon) were overwritten, so operators can
size the window.

These primitives are shared by every layer: the single-device
:class:`~repro.engine.engine.StreamEngine` carries a :class:`WindowState`
through its ``lax.scan``, the sharded engine gives each device its own
ring shard, and :mod:`repro.core.blocked` / :mod:`repro.core.distributed`
re-export them for compatibility.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "WindowState",
    "init_window",
    "push_batch",
    "push_batch_masked",
    "push_with_overflow",
]

_EMPTY_T = jnp.float32(3.0e30)


class WindowState(NamedTuple):
    """Sharded ring buffer of recent stream items (a pytree).

    ``sids`` is the stream-id lane of the multi-tenant runtime
    (DESIGN.md §9): each slot remembers which logical stream its item
    belongs to, so the join can mask cross-stream pairs on device.  It is
    last and defaults to ``None`` so legacy constructions (and pytrees
    that never multiplex streams, e.g. ``core/distributed.py``) stay
    valid — ``None`` is simply an absent pytree leaf.
    """

    vecs: jax.Array    # (capacity, d) f32
    ts: jax.Array      # (capacity,) f32; empty slots hold +3e30
    uids: jax.Array    # (capacity,) i32; empty slots hold -1
    cursor: jax.Array  # () i32 — next write slot
    overflow: jax.Array  # () i32 — live items overwritten (window undersized)
    sids: Optional[jax.Array] = None  # (capacity,) i32 stream ids; -1 = empty


def init_window(capacity: int, d: int, dtype=jnp.float32) -> WindowState:
    return WindowState(
        vecs=jnp.zeros((capacity, d), dtype),
        ts=jnp.full((capacity,), _EMPTY_T, jnp.float32),
        uids=jnp.full((capacity,), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
        sids=jnp.full((capacity,), -1, jnp.int32),
    )


def _sid_rows(sq: Optional[jax.Array], b: int) -> jax.Array:
    return jnp.zeros((b,), jnp.int32) if sq is None else sq.astype(jnp.int32)


def push_batch(
    state: WindowState,
    q: jax.Array,
    tq: jax.Array,
    uq: jax.Array,
    sq: Optional[jax.Array] = None,
) -> WindowState:
    cap = state.ts.shape[0]
    b = q.shape[0]
    pos = (state.cursor + jnp.arange(b, dtype=jnp.int32)) % cap
    return state._replace(
        vecs=state.vecs.at[pos].set(q.astype(state.vecs.dtype)),
        ts=state.ts.at[pos].set(tq.astype(jnp.float32)),
        uids=state.uids.at[pos].set(uq.astype(jnp.int32)),
        cursor=(state.cursor + b) % cap,
        sids=None if state.sids is None
        else state.sids.at[pos].set(_sid_rows(sq, b)),
    )


def push_batch_masked(
    state: WindowState,
    q: jax.Array,
    tq: jax.Array,
    uq: jax.Array,
    n_valid: jax.Array,
    sq: Optional[jax.Array] = None,
) -> WindowState:
    """Push only the first ``n_valid`` rows (the rest are scan padding).

    Writes for invalid rows are routed out of bounds and dropped, and the
    cursor advances by ``n_valid`` — a padded micro-batch therefore leaves
    the ring byte-identical to an unpadded push of the valid prefix, which
    is what makes results invariant to the micro-batch split (tested by
    ``test_engine.py::test_scan_carry_determinism``).
    """
    cap = state.ts.shape[0]
    b = q.shape[0]
    lanes = jnp.arange(b, dtype=jnp.int32)
    pos = (state.cursor + lanes) % cap
    dest = jnp.where(lanes < n_valid, pos, cap)   # cap is OOB → dropped
    return state._replace(
        vecs=state.vecs.at[dest].set(q.astype(state.vecs.dtype), mode="drop"),
        ts=state.ts.at[dest].set(tq.astype(jnp.float32), mode="drop"),
        uids=state.uids.at[dest].set(uq.astype(jnp.int32), mode="drop"),
        cursor=(state.cursor + n_valid.astype(jnp.int32)) % cap,
        sids=None if state.sids is None
        else state.sids.at[dest].set(_sid_rows(sq, b), mode="drop"),
    )


def push_with_overflow(
    state: WindowState,
    q: jax.Array,
    tq: jax.Array,
    uq: jax.Array,
    n_valid: jax.Array,
    t_max: jax.Array,
    tau: float,
    sq: Optional[jax.Array] = None,
) -> WindowState:
    """Masked push that also counts live-slot overwrites.

    A slot is *live* if it holds a real item (uid ≥ 0) still within the
    horizon ``tau`` of the newest arrival ``t_max``; overwriting one means
    the window is undersized and emission becomes best-effort, so the
    ``overflow`` counter records it for the operator.
    """
    cap = state.ts.shape[0]
    lanes = jnp.arange(q.shape[0], dtype=jnp.int32)
    valid = lanes < n_valid
    pos = (state.cursor + lanes) % cap
    live = valid & (state.uids[pos] >= 0) & (t_max - state.ts[pos] <= tau)
    new_state = push_batch_masked(state, q, tq, uq, n_valid, sq=sq)
    return new_state._replace(
        overflow=state.overflow + jnp.sum(live.astype(jnp.int32))
    )
