"""LM-backed document embedder for the SSSJ service.

Any configured architecture (``--arch``) embeds a batch of token sequences:
final-layer hidden states are mean-pooled over non-pad positions and
ℓ2-normalized — unit vectors, the paper's input representation.

:func:`pooled_unit_embed` is the single source of truth for that mapping:
:class:`LMEmbedder` jits it for host-side use, and the multi-tenant
runtime's fused embed→join path (:mod:`repro.runtime`) traces the *same
function* inside its join scan — which is what makes the fused path
bit-identical to the host round trip (tested in ``tests/test_runtime.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.lm import init_lm, lm_forward

__all__ = ["LMEmbedder", "pooled_unit_embed"]


def pooled_unit_embed(
    params, cfg: ModelConfig, tokens: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Tokens ``(B, S)`` → unit embeddings ``(B, d_model)`` (f32, traced).

    Mean-pool final hidden states over non-pad (``token != 0``) positions,
    then ℓ2-normalize.  Pure row-wise math: an all-pad row embeds to the
    zero vector (inert under the join's cosine threshold).
    """
    if mask is None:
        mask = tokens != 0
    _, _, _, hidden = lm_forward(
        params, cfg, tokens=tokens, return_hidden=True,
        compute_dtype=jnp.float32,
    )
    m = mask.astype(jnp.float32)[..., None]
    pooled = (hidden.astype(jnp.float32) * m).sum(1) / jnp.maximum(
        m.sum(1), 1.0
    )
    norm = jnp.linalg.norm(pooled, axis=-1, keepdims=True)
    return pooled / jnp.maximum(norm, 1e-9)


class LMEmbedder:
    def __init__(self, cfg: ModelConfig, params=None, key=None):
        self.cfg = cfg
        if params is None:
            params = init_lm(key if key is not None else jax.random.key(0), cfg)
        self.params = params

        @jax.jit
        def _embed(params, tokens, mask):
            return pooled_unit_embed(params, cfg, tokens, mask)

        self._embed = _embed

    def __call__(self, tokens: np.ndarray, mask: Optional[np.ndarray] = None):
        tokens = np.asarray(tokens, np.int32)
        if mask is None:
            mask = (tokens != 0)
        out = self._embed(self.params, jnp.asarray(tokens), jnp.asarray(mask))
        return np.asarray(out)
