"""LM-backed document embedder for the SSSJ service.

Any configured architecture (``--arch``) embeds a batch of token sequences:
final-layer hidden states are mean-pooled over non-pad positions and
ℓ2-normalized — unit vectors, the paper's input representation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.lm import init_lm, lm_forward

__all__ = ["LMEmbedder"]


class LMEmbedder:
    def __init__(self, cfg: ModelConfig, params=None, key=None):
        self.cfg = cfg
        if params is None:
            params = init_lm(key if key is not None else jax.random.key(0), cfg)
        self.params = params

        @jax.jit
        def _embed(params, tokens, mask):
            _, _, _, hidden = lm_forward(
                params, cfg, tokens=tokens, return_hidden=True,
                compute_dtype=jnp.float32,
            )
            m = mask.astype(jnp.float32)[..., None]
            pooled = (hidden.astype(jnp.float32) * m).sum(1) / jnp.maximum(
                m.sum(1), 1.0
            )
            norm = jnp.linalg.norm(pooled, axis=-1, keepdims=True)
            return pooled / jnp.maximum(norm, 1e-9)

        self._embed = _embed

    def __call__(self, tokens: np.ndarray, mask: Optional[np.ndarray] = None):
        tokens = np.asarray(tokens, np.int32)
        if mask is None:
            mask = (tokens != 0)
        out = self._embed(self.params, jnp.asarray(tokens), jnp.asarray(mask))
        return np.asarray(out)
