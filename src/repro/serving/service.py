"""SSSJ serving loop: batched requests → embeddings → similar-pair events.

This is the paper's system as a *service*: timestamped documents arrive in
request batches; each batch is embedded (LM backbone or caller-provided
vectors), unit-normalized, and fed to the device-resident
:class:`repro.engine.StreamEngine`; the compacted pair arrays it drains
drive near-duplicate grouping (union-find) — application #2 — or trend
detection (growing groups within the horizon) — application #1.

:class:`MultiTenantSSSJService` is the same loop over the multi-tenant
runtime (DESIGN.md §9): many logical streams coalesce onto one engine,
each with its own ``(θ, λ)``, and the union-find keys are **namespaced**
``(tenant, uid)`` tuples — the device join already guarantees no
cross-stream pair exists, and the namespacing makes cross-tenant grouping
structurally impossible on the host too.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..engine.engine import EngineConfig, StreamEngine
from ..engine.window import quota_partition
from ..runtime import (
    FusedEmbedder,
    MultiTenantRuntime,
    ShardedFacade,
    TenantTable,
)

__all__ = [
    "SSSJService",
    "ServiceStats",
    "MultiTenantSSSJService",
]


@dataclasses.dataclass
class ServiceStats:
    n_items: int = 0
    n_pairs: int = 0
    n_groups: int = 0
    window_overflow: int = 0
    pairs_dropped: int = 0
    bytes_to_host: int = 0


class _UnionFind:
    """Union-find with two-pass path compression and union by size."""

    __slots__ = ("parent", "size")

    def __init__(self) -> None:
        self.parent: Dict[int, int] = {}
        self.size: Dict[int, int] = {}

    def find(self, x: int) -> int:
        parent = self.parent
        root = parent.get(x)
        if root is None:
            parent[x] = x
            self.size[x] = 1
            return x
        # pass 1: walk to the root
        while parent[root] != root:
            root = parent[root]
        # pass 2: point every node on the path straight at the root
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]


class SSSJService:
    """Streaming near-duplicate / trend service over an embedding stream."""

    def __init__(
        self,
        theta: float,
        lam: float,
        dim: int,
        capacity: int = 4096,
        embed_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        block: int = 64,
        max_pairs: int = 4096,
        strict: bool = True,
        tile_k: Optional[int] = None,
    ) -> None:
        """``strict`` keeps the pre-engine lossless contract: a request
        whose emission overflows — the global ``max_pairs`` budget or a
        per-tile ``tile_k`` candidate buffer — raises instead of silently
        grouping on a truncated pair set.  Strict mode therefore defaults
        ``tile_k`` to the lossless ``block²`` so the budget is the only
        way to lose a pair; pass ``strict=False`` to accept best-effort
        grouping (smaller ``tile_k``, watch ``stats.pairs_dropped``)."""
        if tile_k is None:
            tile_k = block * block if strict else 256
        cfg = EngineConfig(
            theta=theta, lam=lam, capacity=capacity, d=dim,
            micro_batch=block, max_pairs=max_pairs, tile_k=tile_k,
            block_q=block, block_w=block, chunk_d=min(dim, 128),
        )
        self.engine = StreamEngine(cfg)
        self.embed_fn = embed_fn
        self.strict = strict
        self.groups = _UnionFind()
        self.stats = ServiceStats()

    # ------------------------------------------------------------------ #
    def submit(
        self,
        batch: np.ndarray,           # (B, dim) vectors or (B, S) tokens
        timestamps: np.ndarray,      # (B,)
    ) -> List[Tuple[int, int, float]]:
        """Process one request batch; returns the emitted similar pairs
        (uid_newer, uid_older, decayed_score)."""
        if self.embed_fn is not None and batch.ndim == 2 and batch.dtype.kind in "iu":
            vecs = self.embed_fn(batch)
        else:
            vecs = np.asarray(batch, np.float32)
            norms = np.linalg.norm(vecs, axis=1, keepdims=True)
            vecs = vecs / np.maximum(norms, 1e-9)
        dropped_before = self.engine.pairs_dropped
        self.engine.push(vecs, np.asarray(timestamps, np.float64))
        dropped = self.engine.pairs_dropped - dropped_before
        if dropped and self.strict:
            # surviving pairs stay queued for recovery via engine.drain_*
            raise RuntimeError(
                f"emission overflow: {dropped} pairs dropped this request "
                f"(max_pairs={self.engine.cfg.max_pairs} per micro-batch); "
                f"raise max_pairs or construct SSSJService(strict=False)"
            )
        # one sync per request batch: the compacted arrays, not dense scores
        ua, ub, sc = self.engine.drain_arrays()
        pairs = list(zip(ua.tolist(), ub.tolist(), sc.tolist()))
        union = self.groups.union
        for a, b, _ in pairs:
            union(a, b)
        self.stats.n_items += vecs.shape[0]
        self.stats.n_pairs += len(pairs)
        self.stats.window_overflow = self.engine.overflow
        self.stats.pairs_dropped = self.engine.pairs_dropped
        self.stats.bytes_to_host = self.engine.bytes_to_host
        return pairs

    # ------------------------------------------------------------------ #
    def duplicate_groups(self) -> List[List[int]]:
        """Connected components of the similar-pair graph (app #2)."""
        comp: Dict[int, List[int]] = {}
        for x in list(self.groups.parent):
            comp.setdefault(self.groups.find(x), []).append(x)
        groups = [sorted(v) for v in comp.values() if len(v) > 1]
        self.stats.n_groups = len(groups)
        return sorted(groups)

    def trending(self, min_size: int = 3) -> List[List[int]]:
        """Groups that reached ``min_size`` — the paper's trend-detection
        application (a burst of mutually-similar items within the horizon)."""
        return [g for g in self.duplicate_groups() if len(g) >= min_size]

    # -- observability (DESIGN.md §12) --------------------------------- #
    @property
    def registry(self):
        """The engine's :class:`~repro.obs.MetricsRegistry`."""
        return self.engine.registry

    def snapshot(self) -> dict:
        """One coherent namespaced metrics snapshot (``engine/…``)."""
        return self.engine.registry.snapshot()

    def prometheus_text(self) -> str:
        """The same snapshot in Prometheus text exposition format."""
        return self.engine.registry.prometheus_text()


class MultiTenantSSSJService:
    """Near-duplicate / trend service over K coalesced logical streams.

    One device engine serves every tenant (DESIGN.md §9): ``submit``
    enqueues a tenant's documents, ``flush`` coalesces queued arrivals
    across tenants into full micro-batches, drains the emitted pairs, and
    unions them under **namespaced** keys ``(tenant, uid)`` — so even a
    host-side bug could never merge two tenants' groups.  Per-tenant
    ``(θ, λ)`` comes from the :class:`~repro.runtime.TenantTable`; vectors
    are unit-normalized here (or embedded on device via ``fused``).

    Pass ``mesh`` to run the same service on the **sharded** engine
    (DESIGN.md §10): ``capacity`` stays the *total* window size, split
    evenly across the mesh's window-axis shards; emissions — and therefore
    groups — are identical to the single-device run.

    ``eviction`` selects the window's write-slot policy (DESIGN.md §11):
    ``"oldest"`` (default), ``"dead"`` (reuse expired slots first), or
    ``"quota"`` — a static partition of the window into per-tenant
    sub-rings, so a bursty tenant can only evict its own items.
    ``quotas`` gives each tenant's **total** slot count (summing to
    ``capacity``; default: split by equal weights); on a mesh every quota
    must also divide evenly across the shards, because sub-rings stay
    shard-local.
    """

    def __init__(
        self,
        table: TenantTable,
        dim: int,
        capacity: int = 4096,
        micro_batch: int = 64,
        max_pairs: int = 4096,
        tile_k: Optional[int] = None,
        span: int = 4,
        max_queue_per_tenant: int = 65536,
        fused: Optional[FusedEmbedder] = None,
        mesh=None,
        eviction: str = "oldest",
        quotas: Optional[Sequence[int]] = None,
    ) -> None:
        engine = None
        n = 1
        if mesh is not None:
            engine = ShardedFacade(mesh)
            n = engine.n_shards
            if capacity % n:
                raise ValueError(
                    f"capacity {capacity} not divisible by {n} window shards"
                )
            if micro_batch > capacity // n:
                # EngineConfig validates rings per shard (its capacity is
                # the per-shard size), so state the per-shard math here
                # instead of surfacing a confusing downstream error
                raise ValueError(
                    f"micro_batch ({micro_batch}) exceeds the per-shard "
                    f"window capacity ({capacity // n} = {capacity} total / "
                    f"{n} shards); raise capacity to ≥ {micro_batch * n} "
                    f"or lower micro_batch"
                )
        if eviction == "quota" and quotas is None:
            # partition per shard and scale back up, so the default split
            # always passes the shard-divisibility check below
            quotas = tuple(
                q * n
                for q in quota_partition(capacity // n, [1.0] * table.n_tenants)
            )
        if quotas is not None:
            # per-tenant quota validation happens here, against the caller's
            # TOTAL capacity, before anything is divided per shard
            if eviction != "quota":
                raise ValueError(
                    f"quotas are only meaningful under eviction='quota' "
                    f"(got eviction={eviction!r})"
                )
            quotas = [int(q) for q in quotas]
            if len(quotas) != table.n_tenants:
                raise ValueError(
                    f"{len(quotas)} quotas for {table.n_tenants} tenants"
                )
            if min(quotas) < 1:
                raise ValueError(f"every tenant needs ≥ 1 slot, got {quotas}")
            if sum(quotas) != capacity:
                raise ValueError(
                    f"quotas sum to {sum(quotas)}, not capacity {capacity}"
                )
            bad = [q for q in quotas if q % n]
            if bad:
                raise ValueError(
                    f"quotas {bad} not divisible by {n} window shards "
                    f"(sub-rings are shard-local)"
                )
            quotas = tuple(q // n for q in quotas)
        capacity //= n
        th0, lm0 = table.spec(0)
        cfg = EngineConfig(
            theta=th0, lam=lm0, capacity=capacity, d=dim,
            micro_batch=micro_batch, max_pairs=max_pairs,
            tile_k=tile_k or micro_batch * micro_batch,
            block_q=micro_batch, block_w=micro_batch,
            chunk_d=min(dim, 128),
            eviction=eviction, quotas=quotas,
        )
        self.runtime = MultiTenantRuntime(
            cfg, table, span=span,
            max_queue_per_tenant=max_queue_per_tenant, fused=fused,
            engine=engine,
        )
        self.table = table
        self.fused = fused
        self.groups = _UnionFind()
        # global uid → per-tenant local uid (dense per-tenant numbering, the
        # namespace the caller reasons in)
        self._local_of: Dict[int, int] = {}
        self._next_local = [0] * table.n_tenants

    # ------------------------------------------------------------------ #
    def submit(
        self,
        tenant: int,
        batch: np.ndarray,           # (B, dim) vectors or (B, S) tokens
        timestamps: np.ndarray,      # (B,)
    ) -> np.ndarray:
        """Enqueue one tenant's documents; returns their *local* uids.

        Nothing reaches the device until :meth:`flush` — that is the point:
        a tenant submitting 3 documents at a time still rides full
        micro-batches once enough tenants queue up.
        """
        if self.fused is None:
            vecs = np.asarray(batch, np.float32)
            norms = np.linalg.norm(vecs, axis=1, keepdims=True)
            batch = vecs / np.maximum(norms, 1e-9)
        uids = self.runtime.submit(tenant, batch, np.asarray(timestamps))
        base = self._next_local[tenant]
        local = np.arange(base, base + uids.size, dtype=np.int64)
        self._next_local[tenant] = base + uids.size
        for g, l in zip(uids.tolist(), local.tolist()):
            self._local_of[g] = l
        return local

    def flush(
        self, final: bool = False
    ) -> Dict[int, List[Tuple[int, int, float]]]:
        """Dispatch queued arrivals, drain, and group the emitted pairs.

        Defaults to ``final=False`` — the coalescing contract: only full
        micro-batches dispatch, rows short of one stay queued (same default
        as :meth:`MultiTenantRuntime.flush`).  Pass ``final=True`` at end
        of stream or on a latency deadline to pad the tail out.  Returns
        ``{tenant: [(local_uid_newer, local_uid_older, score)]}`` for
        tenants that emitted anything this flush.
        """
        self.runtime.flush(final=final)
        per = self.runtime.drain_by_tenant()
        out: Dict[int, List[Tuple[int, int, float]]] = {}
        union = self.groups.union
        loc = self._local_of
        for t, (ua, ub, sc) in per.items():
            if ua.size == 0:
                continue
            pairs = [
                (loc[a], loc[b], s)
                for a, b, s in zip(ua.tolist(), ub.tolist(), sc.tolist())
            ]
            for a, b, _ in pairs:
                union((t, a), (t, b))          # namespaced: (tenant, uid)
            out[t] = pairs
        return out

    # ------------------------------------------------------------------ #
    def duplicate_groups(self, tenant: int) -> List[List[int]]:
        """Connected components of one tenant's similar-pair graph."""
        comp: Dict[Hashable, List[int]] = {}
        for key in list(self.groups.parent):
            t, u = key
            if t != tenant:
                continue
            comp.setdefault(self.groups.find(key), []).append(u)
        return sorted(sorted(v) for v in comp.values() if len(v) > 1)

    def trending(self, tenant: int, min_size: int = 3) -> List[List[int]]:
        return [
            g for g in self.duplicate_groups(tenant) if len(g) >= min_size
        ]

    def tenant_stats(self, tenant: int) -> dict:
        return self.runtime.tenant_stats(tenant)

    def stats(self) -> dict:
        return self.runtime.stats()

    # -- observability (DESIGN.md §12) --------------------------------- #
    @property
    def registry(self):
        """The shared :class:`~repro.obs.MetricsRegistry` — engine,
        router, per-tenant, span, and latency metrics in one instance."""
        return self.runtime.registry

    def snapshot(self) -> dict:
        """One coherent namespaced metrics snapshot (``engine/…``,
        ``router/…``, ``runtime/…``, ``span/…``, ``tenant/<k>/…``,
        ``latency/…``)."""
        return self.runtime.registry.snapshot()

    def prometheus_text(self) -> str:
        """The same snapshot in Prometheus text exposition format."""
        return self.runtime.registry.prometheus_text()
