"""SSSJ serving loop: batched requests → embeddings → similar-pair events.

This is the paper's system as a *service*: timestamped documents arrive in
request batches; each batch is embedded (LM backbone or caller-provided
vectors), unit-normalized, and fed to the device-resident
:class:`repro.engine.StreamEngine`; the compacted pair arrays it drains
drive near-duplicate grouping (union-find) — application #2 — or trend
detection (growing groups within the horizon) — application #1.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..engine.engine import EngineConfig, StreamEngine

__all__ = ["SSSJService", "ServiceStats"]


@dataclasses.dataclass
class ServiceStats:
    n_items: int = 0
    n_pairs: int = 0
    n_groups: int = 0
    window_overflow: int = 0
    pairs_dropped: int = 0
    bytes_to_host: int = 0


class _UnionFind:
    """Union-find with two-pass path compression and union by size."""

    __slots__ = ("parent", "size")

    def __init__(self) -> None:
        self.parent: Dict[int, int] = {}
        self.size: Dict[int, int] = {}

    def find(self, x: int) -> int:
        parent = self.parent
        root = parent.get(x)
        if root is None:
            parent[x] = x
            self.size[x] = 1
            return x
        # pass 1: walk to the root
        while parent[root] != root:
            root = parent[root]
        # pass 2: point every node on the path straight at the root
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]


class SSSJService:
    """Streaming near-duplicate / trend service over an embedding stream."""

    def __init__(
        self,
        theta: float,
        lam: float,
        dim: int,
        capacity: int = 4096,
        embed_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        block: int = 64,
        max_pairs: int = 4096,
        strict: bool = True,
        tile_k: Optional[int] = None,
    ) -> None:
        """``strict`` keeps the pre-engine lossless contract: a request
        whose emission overflows — the global ``max_pairs`` budget or a
        per-tile ``tile_k`` candidate buffer — raises instead of silently
        grouping on a truncated pair set.  Strict mode therefore defaults
        ``tile_k`` to the lossless ``block²`` so the budget is the only
        way to lose a pair; pass ``strict=False`` to accept best-effort
        grouping (smaller ``tile_k``, watch ``stats.pairs_dropped``)."""
        if tile_k is None:
            tile_k = block * block if strict else 256
        cfg = EngineConfig(
            theta=theta, lam=lam, capacity=capacity, d=dim,
            micro_batch=block, max_pairs=max_pairs, tile_k=tile_k,
            block_q=block, block_w=block, chunk_d=min(dim, 128),
        )
        self.engine = StreamEngine(cfg)
        self.embed_fn = embed_fn
        self.strict = strict
        self.groups = _UnionFind()
        self.stats = ServiceStats()

    # ------------------------------------------------------------------ #
    def submit(
        self,
        batch: np.ndarray,           # (B, dim) vectors or (B, S) tokens
        timestamps: np.ndarray,      # (B,)
    ) -> List[Tuple[int, int, float]]:
        """Process one request batch; returns the emitted similar pairs
        (uid_newer, uid_older, decayed_score)."""
        if self.embed_fn is not None and batch.ndim == 2 and batch.dtype.kind in "iu":
            vecs = self.embed_fn(batch)
        else:
            vecs = np.asarray(batch, np.float32)
            norms = np.linalg.norm(vecs, axis=1, keepdims=True)
            vecs = vecs / np.maximum(norms, 1e-9)
        dropped_before = self.engine.pairs_dropped
        self.engine.push(vecs, np.asarray(timestamps, np.float64))
        dropped = self.engine.pairs_dropped - dropped_before
        if dropped and self.strict:
            # surviving pairs stay queued for recovery via engine.drain_*
            raise RuntimeError(
                f"emission overflow: {dropped} pairs dropped this request "
                f"(max_pairs={self.engine.cfg.max_pairs} per micro-batch); "
                f"raise max_pairs or construct SSSJService(strict=False)"
            )
        # one sync per request batch: the compacted arrays, not dense scores
        ua, ub, sc = self.engine.drain_arrays()
        pairs = list(zip(ua.tolist(), ub.tolist(), sc.tolist()))
        union = self.groups.union
        for a, b, _ in pairs:
            union(a, b)
        self.stats.n_items += vecs.shape[0]
        self.stats.n_pairs += len(pairs)
        self.stats.window_overflow = self.engine.overflow
        self.stats.pairs_dropped = self.engine.pairs_dropped
        self.stats.bytes_to_host = self.engine.bytes_to_host
        return pairs

    # ------------------------------------------------------------------ #
    def duplicate_groups(self) -> List[List[int]]:
        """Connected components of the similar-pair graph (app #2)."""
        comp: Dict[int, List[int]] = {}
        for x in list(self.groups.parent):
            comp.setdefault(self.groups.find(x), []).append(x)
        groups = [sorted(v) for v in comp.values() if len(v) > 1]
        self.stats.n_groups = len(groups)
        return sorted(groups)

    def trending(self, min_size: int = 3) -> List[List[int]]:
        """Groups that reached ``min_size`` — the paper's trend-detection
        application (a burst of mutually-similar items within the horizon)."""
        return [g for g in self.duplicate_groups() if len(g) >= min_size]
