"""SSSJ serving loop: batched requests → embeddings → similar-pair events.

This is the paper's system as a *service*: timestamped documents arrive in
request batches; each batch is embedded (LM backbone or caller-provided
vectors), unit-normalized, and joined against the recent-past window; the
emitted pairs drive near-duplicate grouping (union-find) — application #2 —
or trend detection (growing groups within the horizon) — application #1.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.blocked import BlockedJoinConfig, BlockedStreamJoiner

__all__ = ["SSSJService", "ServiceStats"]


@dataclasses.dataclass
class ServiceStats:
    n_items: int = 0
    n_pairs: int = 0
    n_groups: int = 0
    window_overflow: int = 0


class _UnionFind:
    def __init__(self) -> None:
        self.parent: Dict[int, int] = {}

    def find(self, x: int) -> int:
        p = self.parent.setdefault(x, x)
        while p != self.parent.get(p, p):
            self.parent[x] = self.parent[p]
            p = self.parent[p]
        return p

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


class SSSJService:
    """Streaming near-duplicate / trend service over an embedding stream."""

    def __init__(
        self,
        theta: float,
        lam: float,
        dim: int,
        capacity: int = 4096,
        embed_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        block: int = 64,
    ) -> None:
        cfg = BlockedJoinConfig(
            theta=theta, lam=lam, capacity=capacity, d=dim,
            block_q=block, block_w=block, chunk_d=min(dim, 128),
        )
        self.joiner = BlockedStreamJoiner(cfg)
        self.embed_fn = embed_fn
        self.groups = _UnionFind()
        self.stats = ServiceStats()
        self._group_members: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------ #
    def submit(
        self,
        batch: np.ndarray,           # (B, dim) vectors or (B, S) tokens
        timestamps: np.ndarray,      # (B,)
    ) -> List[Tuple[int, int, float]]:
        """Process one request batch; returns the emitted similar pairs
        (uid_newer, uid_older, decayed_score)."""
        if self.embed_fn is not None and batch.ndim == 2 and batch.dtype.kind in "iu":
            vecs = self.embed_fn(batch)
        else:
            vecs = np.asarray(batch, np.float32)
            norms = np.linalg.norm(vecs, axis=1, keepdims=True)
            vecs = vecs / np.maximum(norms, 1e-9)
        pairs = self.joiner.push(vecs, np.asarray(timestamps, np.float64))
        for a, b, _ in pairs:
            self.groups.union(a, b)
        self.stats.n_items += vecs.shape[0]
        self.stats.n_pairs += len(pairs)
        self.stats.window_overflow = self.joiner.overflow
        return pairs

    # ------------------------------------------------------------------ #
    def duplicate_groups(self) -> List[List[int]]:
        """Connected components of the similar-pair graph (app #2)."""
        comp: Dict[int, List[int]] = {}
        for x in self.groups.parent:
            comp.setdefault(self.groups.find(x), []).append(x)
        groups = [sorted(v) for v in comp.values() if len(v) > 1]
        self.stats.n_groups = len(groups)
        return sorted(groups)

    def trending(self, min_size: int = 3) -> List[List[int]]:
        """Groups that reached ``min_size`` — the paper's trend-detection
        application (a burst of mutually-similar items within the horizon)."""
        return [g for g in self.duplicate_groups() if len(g) >= min_size]
