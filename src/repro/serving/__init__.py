"""Serving: LM embedder + streaming similarity self-join service."""

from .embedder import LMEmbedder  # noqa: F401
from .service import SSSJService, ServiceStats  # noqa: F401
