"""Serving: LM embedder + streaming similarity self-join services
(single-stream and multi-tenant)."""

from .embedder import LMEmbedder, pooled_unit_embed  # noqa: F401
from .service import (  # noqa: F401
    MultiTenantSSSJService,
    SSSJService,
    ServiceStats,
)
