"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* jax
initializes, and smoke tests must keep seeing 1 device.

Topology (TPU v5e):
  single-pod  (data=16, model=16)           — 256 chips, all-ICI
  multi-pod   (pod=2, data=16, model=16)    — 512 chips; the leading "pod"
                                              axis crosses DCN
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax ≥ 0.6: meshes carry explicit/auto axis types
    from jax.sharding import AxisType

    def _mesh(grid, axes) -> Mesh:
        return Mesh(grid, axes, axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax 0.4.x: every axis is implicitly "auto"
    def _mesh(grid, axes) -> Mesh:
        return Mesh(grid, axes)

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_for(shape, axes)


def make_mesh_for(shape, axes) -> Mesh:
    """Build a mesh over the first prod(shape) available devices."""
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {dict(zip(axes, shape))}, "
            f"have {len(devs)} — did you set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (see launch/dryrun.py)?"
        )
    grid = np.asarray(devs[:n]).reshape(shape)
    return _mesh(grid, axes)
