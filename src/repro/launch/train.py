"""Training driver: mesh-aware, checkpointed, health-tracked.

Runs a real training loop for any ``--arch`` (reduced configs fit this CPU
container; full configs need the production mesh).  Features exercised:

  * sharded train step (pjit over whatever mesh the device set supports),
  * resumable data pipeline with optional SSSJ streaming dedup,
  * CheckpointManager (atomic, async, retention, exact resume),
  * HeartbeatTracker hooks (single-host here, but the loop structure is the
    multi-host one: beat → check dead/stragglers → re-plan on change).

Example (CPU, ~1 minute):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 20 --batch 4 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data.pipeline import DedupFilter, TokenPipeline
from repro.distributed.sharding import DEFAULT_RULES, param_shardings, use_rules
from repro.ft.health import HeartbeatTracker
from repro.ft.manager import CheckpointManager
from repro.launch.mesh import make_mesh_for
from repro.models.lm import lm_specs
from repro.optim.adamw import AdamWConfig, opt_state_specs
from repro.train.step import TrainConfig, build_train_step, init_train_state

__all__ = ["run_training"]


def run_training(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 50,
    batch: int = 4,
    seq: int = 128,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    dedup: bool = False,
    mesh_shape=None,
    peak_lr: float = 1e-3,
    log_every: int = 5,
):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(peak_lr=peak_lr, warmup_steps=max(steps // 10, 2),
                              total_steps=steps),
        remat=True,
        microbatches=1,
    )

    n_dev = len(jax.devices())
    if mesh_shape is None:
        mesh_shape = (n_dev, 1)
    mesh = make_mesh_for(mesh_shape, ("data", "model"))
    rules = DEFAULT_RULES

    params, opt_state = init_train_state(jax.random.key(0), cfg, tcfg)
    with use_rules(mesh, rules):
        p_specs = lm_specs(cfg)
        p_sh = param_shardings(p_specs, params, mesh, rules)
        o_sh = param_shardings(
            opt_state_specs(p_specs, tcfg.optimizer), opt_state, mesh, rules
        )
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    base_step = build_train_step(cfg, tcfg)

    def stepper(p, o, b):
        with use_rules(mesh, rules):
            return base_step(p, o, b)

    step_fn = jax.jit(stepper, donate_argnums=(0, 1))

    pipe = TokenPipeline(
        vocab_size=cfg.vocab_size, batch=batch, seq_len=seq, seed=1,
        dup_frac=0.2 if dedup else 0.0,
        dedup=DedupFilter() if dedup else None,
    )
    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    health = HeartbeatTracker()

    start = 0
    if mgr is not None:
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            state, extra, start = restored
            params, opt_state = state["params"], state["opt"]
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
            if "pipeline" in extra:
                pipe.restore_state(extra["pipeline"])
            print(f"resumed from step {start}")

    history = []
    for i in range(start, steps):
        b = pipe.next_batch()
        batch_dev = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        history.append(loss)
        health.record("host0", i, time.time())
        if i % log_every == 0 or i == steps - 1:
            dt = time.time() - t0
            extra_s = ""
            if dedup:
                extra_s = (f"  dedup_dropped={pipe.dedup.n_dropped}"
                           f"/{pipe.dedup.n_seen}")
            print(f"step {i:5d}  loss {loss:.4f}  gnorm "
                  f"{float(metrics['grad_norm']):.3f}  {dt*1e3:.0f} ms{extra_s}")
        if mgr is not None and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state},
                     extra={"pipeline": pipe.checkpoint_state()})
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state},
                 extra={"pipeline": pipe.checkpoint_state()})
        mgr.wait()
    return params, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dedup", action="store_true",
                    help="enable the SSSJ streaming-dedup pipeline stage")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    run_training(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        dedup=args.dedup, peak_lr=args.lr,
    )


if __name__ == "__main__":
    main()
