import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, and extract memory / cost / collective statistics.

The two lines above MUST stay the first statements in this file — jax locks
the device count at first initialization, and the 512 placeholder host
devices exist only for this entry point (smoke tests and benches see 1).

Usage:
    # one cell (in-process):
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--json out.json]

    # the full 40-cell × {single,multi}-pod sweep (subprocess per cell, so
    # one pathological compile cannot take the sweep down):
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, cell_enabled, get_config
from repro.launch.cells import make_step_and_inputs
from repro.launch.mesh import make_production_mesh
from repro.roofline import active_param_count, model_flops, roofline_terms
from repro.roofline.hlo import analyze_hlo

__all__ = ["run_cell"]


def _mem_stats(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes", "peak_memory_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_enabled(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "skipped", "reason": why,
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cell = make_step_and_inputs(cfg, shape, mesh)

    t0 = time.time()
    jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
    lowered = jitted.lower(*cell.args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if save_hlo:
        pathlib.Path(save_hlo).write_text(hlo)
    # loop-aware HLO walk (XLA's cost_analysis does not multiply while-loop
    # bodies by trip count, see roofline/hlo.py)
    walk = analyze_hlo(hlo)
    flops = walk.flops
    byac = walk.hbm_bytes

    terms = roofline_terms(flops, byac, walk.total_collective_bytes)
    n_active = active_param_count(cfg)
    mf = model_flops(cfg, shape, n_active)
    mf_per_dev = mf / chips

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_stats(compiled),
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": {
            "bytes_by_kind": {k: float(v) for k, v in walk.collective_bytes.items()},
            "ops": {k: float(v) for k, v in walk.collective_ops.items()},
            "total_bytes": float(walk.total_collective_bytes),
        },
        "top_dot_sites": dict(
            sorted(walk.dot_flops_by_meta.items(), key=lambda kv: -kv[1])[:10]
        ),
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_dev": mf_per_dev,
        "useful_ratio": (mf_per_dev / flops) if flops else None,
        "n_active_params": n_active,
    }
    return rec


def _print_rec(rec: dict) -> None:
    if rec["status"] == "skipped":
        print(f"SKIP  {rec['arch']} × {rec['shape']}: {rec['reason']}")
        return
    r = rec["roofline"]
    mem = rec["memory"]
    print(
        f"OK    {rec['arch']} × {rec['shape']}"
        f" [{'2×16×16' if rec['multi_pod'] else '16×16'}]"
        f"  compile={rec['compile_s']:.1f}s"
    )
    if mem:
        args_gb = mem.get("argument_size_in_bytes", 0) / 2**30
        temp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
        print(f"      memory/device: args={args_gb:.2f} GiB temp={temp_gb:.2f} GiB")
    print(
        f"      roofline/device: compute={r['compute_s']*1e3:.2f} ms"
        f" memory={r['memory_s']*1e3:.2f} ms"
        f" collective={r['collective_s']*1e3:.2f} ms"
        f" → {r['dominant']}-bound"
    )
    ur = rec.get("useful_ratio")
    if ur:
        print(f"      MODEL_FLOPS/HLO_FLOPs = {ur:.3f}")


def _sweep(out_dir: str, multi_pod_only: bool = False) -> int:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    fails = 0
    for arch in ARCHS:
        for shape_name in SHAPES:
            for mp in ((True,) if multi_pod_only else (False, True)):
                tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                dst = out / f"{tag}.json"
                if dst.exists():
                    rec = json.loads(dst.read_text())
                    print(f"cached {tag}: {rec.get('status')}")
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape_name,
                    "--json", str(dst),
                ]
                if mp:
                    cmd.append("--multi-pod")
                print(f"→ {tag}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=3600)
                if r.returncode != 0:
                    fails += 1
                    dst.write_text(json.dumps({
                        "arch": arch, "shape": shape_name, "multi_pod": mp,
                        "status": "error",
                        "error": r.stderr[-4000:],
                    }, indent=2))
                    print(f"FAIL  {tag}\n{r.stderr[-2000:]}")
                else:
                    print(r.stdout.rstrip())
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="full sweep (subprocesses)")
    ap.add_argument("--out", default="results/dryrun", help="sweep output dir")
    ap.add_argument("--json", help="write single-cell record to this path")
    ap.add_argument("--save-hlo", help="dump optimized HLO to this path")
    args = ap.parse_args()

    if args.all:
        sys.exit(_sweep(args.out))

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.save_hlo)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    _print_rec(rec)
    if args.json:
        pathlib.Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.json).write_text(json.dumps(rec, indent=2))
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
