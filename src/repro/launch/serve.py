"""Serving driver: the paper's system end-to-end.

Batched requests (token sequences) → LM embedding (any ``--arch``) →
streaming similarity self-join → near-duplicate groups + trend events,
printed as they are detected.  This is the end-to-end example driver the
paper's kind dictates (a streaming/serving system, not a training recipe).

Example (CPU, seconds):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 32 --batch 16 --theta 0.85 --lam 0.05
"""

from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import ARCHS, get_config
from repro.serving.embedder import LMEmbedder
from repro.serving.service import SSSJService

__all__ = ["run_service"]


def run_service(
    arch: str,
    *,
    requests: int = 32,
    batch: int = 16,
    seq: int = 64,
    theta: float = 0.85,
    lam: float = 0.05,
    dup_frac: float = 0.25,
    seed: int = 0,
    verbose: bool = True,
):
    cfg = get_config(arch).reduced()
    embedder = LMEmbedder(cfg, key=jax.random.key(seed))
    service = SSSJService(
        theta=theta, lam=lam, dim=cfg.d_model, capacity=4096,
        embed_fn=embedder,
    )
    rng = np.random.default_rng(seed)
    t = 0.0
    recent: list[np.ndarray] = []
    planted = 0
    for r in range(requests):
        toks = rng.integers(1, cfg.vocab_size, (batch, seq))
        for i in range(batch):
            if recent and rng.random() < dup_frac:
                src = recent[int(rng.integers(0, len(recent)))]
                noise = rng.random(seq) < 0.05
                toks[i] = np.where(noise, toks[i], src)
                planted += 1
        for i in range(batch):
            recent.append(toks[i].copy())
        recent = recent[-256:]
        ts = t + np.arange(batch) * 0.01
        t += 1.0
        pairs = service.submit(toks.astype(np.int32), ts)
        if verbose and pairs:
            print(f"request batch {r}: {len(pairs)} similar pairs")
    groups = service.duplicate_groups()
    trends = service.trending(min_size=3)
    if verbose:
        es = service.engine.stats()
        print(f"\nitems={service.stats.n_items} planted_dups={planted} "
              f"pairs={service.stats.n_pairs} "
              f"dropped={service.stats.pairs_dropped}")
        print(f"host↔device: {es['bytes_to_host']} B compacted vs "
              f"{es['bytes_dense_equiv']} B dense-equivalent")
        print(f"duplicate groups: {len(groups)}; trending (≥3): {len(trends)}")
        for g in trends[:5]:
            print("  trend:", g)
    return service, groups, trends


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--theta", type=float, default=0.85)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--dup-frac", type=float, default=0.25)
    args = ap.parse_args()
    run_service(
        args.arch, requests=args.requests, batch=args.batch, seq=args.seq,
        theta=args.theta, lam=args.lam, dup_frac=args.dup_frac,
    )


if __name__ == "__main__":
    main()
