"""Dry-run cell construction: (arch × shape) → step fn + sharded arg specs.

``make_step_and_inputs(cfg, shape, mesh)`` returns ``(fn, args, rules)``
where every leaf of ``args`` is a ``jax.ShapeDtypeStruct`` carrying its
``NamedSharding`` — no device memory is ever allocated; the caller does
``jax.jit(fn, ...).lower(*args).compile()``.

Sharding regimes (logical-axis rule tables):

  * **train / prefill** — batch over (pod, data); TP (heads/ff/vocab/experts)
    over model; KV-cache sequence over model (needed to fit 32k×B caches).
  * **decode** — SP-decode: cache kv_seq over model (flash-decode style
    partial-softmax combining), batch over (pod, data); attention heads
    replicated (negligible compute at S=1), MLP/MoE/vocab still TP.
  * **long-context decode** — batch=1 ⇒ batch replicates (divisibility
    fallback); kv_seq over (pod, data, model) = every chip holds a slice of
    the 512k cache.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.sharding import (
    AxisRules, DEFAULT_RULES, param_shardings, resolve_pspec, use_rules,
)
from ..models.lm import (
    init_lm, init_lm_caches, lm_cache_specs, lm_decode_step, lm_forward, lm_specs,
)
from ..optim.adamw import AdamWConfig, init_opt_state, opt_state_specs
from ..train.step import TrainConfig, build_train_step

__all__ = ["make_step_and_inputs", "rules_for", "abstract_train_state",
           "abstract_params", "DryRunCell"]


TRAIN_RULES = DEFAULT_RULES.override(kv_seq=("model",))
PREFILL_RULES = DEFAULT_RULES.override(kv_seq=("model",))
DECODE_RULES = DEFAULT_RULES.override(
    kv_seq=("model",), heads=None, kv_heads=None,
)
LONG_RULES = DEFAULT_RULES.override(
    kv_seq=("pod", "data", "model"), heads=None, kv_heads=None,
)


def rules_for(shape: ShapeConfig, cfg: Optional[ModelConfig] = None) -> AxisRules:
    if shape.kind == "train":
        rules = TRAIN_RULES
    elif shape.kind == "prefill":
        rules = PREFILL_RULES
    elif shape.name == "long_500k":
        return LONG_RULES
    else:
        return DECODE_RULES
    # Perf iteration T3 (sequence parallelism): archs whose head count does
    # not divide the model axis (musicgen 24H, deepseek-coder 56H) cannot
    # TP their attention — heads fall back to replicated, making every
    # device compute ALL heads over its batch shard (16× the attention
    # work/traffic of the sharded case).  Mapping the *sequence* axis onto
    # "model" instead shards attention (and norms/activations) by position:
    # valid for any head count, costs one KV all-gather per layer.
    if cfg is not None and cfg.n_heads % 16 != 0 and cfg.block_kind == "transformer":
        rules = rules.override(seq=("model",), heads=None, kv_heads=None)
    return rules


def _sds(shape, dtype, mesh, pspec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, pspec))


def _attach(shapes_tree, spec_tree, mesh: Mesh, rules: AxisRules):
    """ShapeDtypeStruct tree + logical-spec tree → SDS-with-sharding tree."""
    is_leaf = lambda s: s is None or (
        isinstance(s, tuple) and all(isinstance(x, (str, type(None))) for x in s)
    )

    def one(spec, sds):
        if spec is None:
            ps = P()
        else:
            ps = resolve_pspec(sds.shape, spec, rules, mesh)
        return _sds(sds.shape, sds.dtype, mesh, ps)

    return jax.tree.map(one, spec_tree, shapes_tree, is_leaf=is_leaf)


def abstract_params(cfg: ModelConfig, mesh: Mesh, rules: AxisRules,
                    dtype=None):
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.key(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), shapes
        )
    return _attach(shapes, lm_specs(cfg), mesh, rules)


def abstract_train_state(cfg: ModelConfig, train_cfg: TrainConfig, mesh: Mesh,
                         rules: AxisRules):
    p_sds = abstract_params(cfg, mesh, rules)
    opt_shapes = jax.eval_shape(
        functools.partial(init_opt_state, cfg=train_cfg.optimizer), p_sds
    )
    opt_sds = _attach(
        opt_shapes, opt_state_specs(lm_specs(cfg), train_cfg.optimizer),
        mesh, rules,
    )
    return p_sds, opt_sds


@dataclasses.dataclass
class DryRunCell:
    fn: Callable
    args: Tuple[Any, ...]
    rules: AxisRules
    donate: Tuple[int, ...]
    label: str


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 rules: AxisRules, seq_len: int):
    gb = shape.global_batch
    if cfg.input_kind == "embeddings":
        batch = {
            "embeds": _sds(
                (gb, seq_len, cfg.d_model), jnp.bfloat16, mesh,
                resolve_pspec((gb, seq_len, cfg.d_model),
                              ("batch", "seq", "d_model"), rules, mesh),
            ),
            "labels": _sds(
                (gb, seq_len), jnp.int32, mesh,
                resolve_pspec((gb, seq_len), ("batch", "seq"), rules, mesh),
            ),
        }
    else:
        tok = _sds(
            (gb, seq_len), jnp.int32, mesh,
            resolve_pspec((gb, seq_len), ("batch", "seq"), rules, mesh),
        )
        batch = {"tokens": tok, "labels": tok}
    return batch


def default_train_cfg(cfg: ModelConfig, shape: ShapeConfig,
                      batch_ways: int = 16) -> TrainConfig:
    """Microbatch count sized so one microbatch is ≤ ~64k global tokens
    (bounds the MoE dispatch buffer and activation live set) — but never so
    many that the per-microbatch batch stops dividing the batch-sharding
    ways (on the 2×16×16 mesh batch shards 32 ways; a 16-sequence
    microbatch would silently replicate and 4× the per-device work)."""
    tokens = shape.global_batch * shape.seq_len
    micro = max(1, min(tokens // 65_536, shape.global_batch // batch_ways))
    while micro > 1 and (
        shape.global_batch % micro
        or (shape.global_batch // micro) % batch_ways
    ):
        micro -= 1
    return TrainConfig(
        optimizer=AdamWConfig(
            moment_dtype="int8" if cfg.name == "deepseek-v3-671b" else "f32"
        ),
        remat=True,
        microbatches=micro,
    )


def make_step_and_inputs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    train_cfg: Optional[TrainConfig] = None,
    rules: Optional[AxisRules] = None,
) -> DryRunCell:
    rules = rules or rules_for(shape, cfg)
    label = f"{cfg.name}×{shape.name}"

    if shape.kind == "train":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        batch_ways = sizes.get("pod", 1) * sizes.get("data", 1)
        tc = train_cfg or default_train_cfg(cfg, shape, batch_ways)
        p_sds, opt_sds = abstract_train_state(cfg, tc, mesh, rules)
        batch = _batch_specs(cfg, shape, mesh, rules, shape.seq_len)
        step = build_train_step(cfg, tc)

        def fn(params, opt_state, batch):
            with use_rules(mesh, rules):
                return step(params, opt_state, batch)

        return DryRunCell(fn, (p_sds, opt_sds, batch), rules, (0, 1), label)

    # ---------------- serving paths (bf16 deployment params) -------------
    p_sds = abstract_params(cfg, mesh, rules, dtype=jnp.bfloat16)
    cache_shapes = jax.eval_shape(
        functools.partial(
            init_lm_caches, cfg, shape.global_batch, shape.seq_len,
            dtype=jnp.bfloat16,
        )
    )
    shard_kv = True
    cache_sds = _attach(
        cache_shapes, lm_cache_specs(cfg, shard_kv_seq=shard_kv), mesh, rules
    )

    if shape.kind == "prefill":
        batch = _batch_specs(cfg, shape, mesh, rules, shape.seq_len)

        def fn(params, caches, batch):
            with use_rules(mesh, rules):
                kw = (
                    dict(embeds=batch["embeds"])
                    if cfg.input_kind == "embeddings"
                    else dict(tokens=batch["tokens"])
                )
                logits, aux, new_caches = lm_forward(
                    params, cfg, caches=caches, cache_len=jnp.int32(0), **kw
                )
                # realistic prefill output: last-position logits + caches
                return logits[:, -1:], new_caches

        return DryRunCell(fn, (p_sds, cache_sds, batch), rules, (1,), label)

    # decode: one new token against a cache holding seq_len-1 tokens
    gb = shape.global_batch
    if cfg.input_kind == "embeddings":
        tok = _sds(
            (gb, 1, cfg.d_model), jnp.bfloat16, mesh,
            resolve_pspec((gb, 1, cfg.d_model), ("batch", "seq", "d_model"),
                          rules, mesh),
        )
    else:
        tok = _sds(
            (gb, 1), jnp.int32, mesh,
            resolve_pspec((gb, 1), ("batch", "seq"), rules, mesh),
        )
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, caches, tok, cache_len):
        with use_rules(mesh, rules):
            if cfg.input_kind == "embeddings":
                return lm_decode_step(
                    params, cfg, tokens=None, embeds=tok, caches=caches,
                    cache_len=cache_len,
                )
            return lm_decode_step(
                params, cfg, tokens=tok, caches=caches, cache_len=cache_len
            )

    return DryRunCell(fn, (p_sds, cache_sds, tok, cache_len), rules, (1,), label)
