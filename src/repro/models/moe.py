"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP sharding.

Switch-style capacity-based dispatch (the standard TPU formulation):

  1. router logits → top-k (expert, gate) per token;
  2. position-in-expert via a cumulative sum over the one-hot assignment;
     slots beyond capacity ``C = ceil(T·k/E · capacity_factor)`` are dropped
     (scattered into a dump slot and masked on combine);
  3. tokens are scattered into an ``(E, C, D)`` buffer — sharded over the
     ``experts`` logical axis (EP), so GSPMD materializes the all-to-all;
  4. batched expert SwiGLU; combine = gather + gate-weighted sum.

Routers: "softmax" (OLMoE: softmax → top-k → renormalize) and "sigmoid"
(DeepSeek-V3: sigmoid scores + bias-free top-k → normalize).  A load-
balance auxiliary loss (Switch) is returned for training.

A sort-based (ragged) dispatch that avoids the (T·k·E) one-hot cumsum is a
recorded perf-iteration candidate (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..distributed.sharding import constrain
from .common import Initializer, dense_init

__all__ = ["init_moe", "moe_specs", "moe"]


def moe_specs(cfg: ModelConfig):
    """Logical-axis specs for :func:`init_moe` (no allocation)."""
    mc = cfg.moe
    specs = {
        "router": ("d_model", None),
        "w_gate": ("experts", "fsdp", "expert_ff"),
        "w_up": ("experts", "fsdp", "expert_ff"),
        "w_down": ("experts", "expert_ff", "fsdp"),
    }
    if mc.n_shared_experts:
        specs["shared"] = {
            "w_gate": ("fsdp", "ff"),
            "w_up": ("fsdp", "ff"),
            "w_down": ("ff", "fsdp"),
        }
    return specs


def init_moe(init: Initializer, cfg: ModelConfig):
    mc = cfg.moe
    assert mc is not None
    d, e, f = cfg.d_model, mc.n_experts, mc.d_ff_expert
    params = {
        "router": dense_init(init.next(), (d, e)),
        "w_gate": dense_init(init.next(), (e, d, f)),
        "w_up": dense_init(init.next(), (e, d, f)),
        "w_down": dense_init(init.next(), (e, f, d), in_axis=1),
    }
    if mc.n_shared_experts:
        fs = f * mc.n_shared_experts
        params["shared"] = {
            "w_gate": dense_init(init.next(), (d, fs)),
            "w_up": dense_init(init.next(), (d, fs)),
            "w_down": dense_init(init.next(), (fs, d)),
        }
    return params, moe_specs(cfg)


def _route(mc: MoEConfig, logits: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits (T, E) → (gates (T,k), experts (T,k), probs-for-aux (T, E))."""
    if mc.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        gate, idx = jax.lax.top_k(scores, mc.top_k)
        gate = gate / (gate.sum(-1, keepdims=True) + 1e-20)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate, idx = jax.lax.top_k(probs, mc.top_k)
        gate = gate / (gate.sum(-1, keepdims=True) + 1e-20)
    return gate, idx, probs


def moe(
    params, cfg: ModelConfig, x: jax.Array, dropless: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y (B, S, D), aux_loss scalar).

    ``dropless=True`` sets capacity = T so no token is ever dropped.  This
    makes the layer *causally consistent* (each token's output depends only
    on its own routing, not on batch composition) — required for serving
    correctness (decode must match the full forward).  Training uses the
    capacity-factor dispatch, whose (bounded) drops are the standard TPU
    trade-off.
    """
    mc = cfg.moe
    assert mc is not None
    B, S, D = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    dt = x.dtype
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dt))
    gate, idx, probs = _route(mc, logits)            # (T,K), (T,K), (T,E)

    # load-balance auxiliary loss (Switch): E · Σ_e frac_tokens_e · frac_prob_e
    assign1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(assign1.mean(0) * probs.mean(0))

    # dispatch groups: positions are computed *within* a group so the
    # cumsum has no cross-shard sequential dependency (perf iteration M2,
    # GShard's "local groups").  G matches the data axis; capacity is
    # per-group.
    G = mc.dispatch_groups if (not dropless and T % mc.dispatch_groups == 0) else 1
    tg = T // G                     # tokens per group
    if dropless:
        cap_g = T
    else:
        cap_g = max(int(math.ceil(tg * K / E * mc.capacity_factor)), 4)
    capacity = G * cap_g            # total per-expert slots

    # position of each (token, k) slot within its expert's per-group queue
    onehot = jax.nn.one_hot(idx.reshape(G, tg * K), E, dtype=jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                  # (G, tg·K, E)
    pos = jnp.take_along_axis(
        pos_all, idx.reshape(G, tg * K, 1), axis=2
    )[..., 0].reshape(-1)                                     # (T·K,)
    keep = pos < cap_g
    e_flat = idx.reshape(-1)
    pos_c = jnp.where(keep, pos, cap_g)                       # per-group dump
    g_of = (
        jnp.repeat(jnp.arange(G, dtype=jnp.int32), tg * K)
    )                                                         # (T·K,)

    # Dispatch via GATHER (perf iteration M1): a (T·K, D)-sized scatter
    # into the expert-sharded buffer made GSPMD all-gather a u32[T·K, D]
    # index tensor (4.3 GB/layer for olmoe@train_4k).  Instead: scatter
    # only token *ids* into a small int map, then gather rows — the
    # data-plane collective shrinks to a (T, D) reshard, and the backward
    # is a (T, D) scatter-add instead of an (E, C, D) scatter.
    tok_rep = jnp.tile(
        jnp.repeat(jnp.arange(T, dtype=jnp.int32), K).reshape(T, K), (1, 1)
    ).reshape(-1)
    stride = cap_g + 1
    slot = (
        e_flat.astype(jnp.int32) * (G * stride)
        + g_of * stride
        + pos_c.astype(jnp.int32)
    )
    src_map = jnp.full((E * G * stride,), T, jnp.int32)
    src_map = src_map.at[slot].set(tok_rep, mode="drop")
    src_map = src_map.reshape(E, G, stride)[:, :, :cap_g].reshape(E, capacity)
    src_map = constrain(src_map, "experts", None)
    x_pad = jnp.concatenate([xf, jnp.zeros((1, D), dt)], axis=0)  # dump row
    h = jnp.take(x_pad, src_map, axis=0)                          # (E, C, D)
    h = constrain(h, "experts", None, None)

    # batched expert SwiGLU
    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"].astype(dt))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"].astype(dt))
    o = constrain(o, "experts", None, None)

    # combine: gather each slot's output, weight by gate, sum over k
    o_pad = jnp.concatenate([o, jnp.zeros((E, 1, D), dt)], axis=1)
    col = jnp.where(keep, g_of * cap_g + pos_c, capacity)             # dump col
    out_slots = o_pad[e_flat, col]                                    # (T*K, D)
    w = (gate.reshape(-1) * keep.astype(jnp.float32)).astype(dt)
    y = jax.ops.segment_sum(
        out_slots * w[:, None], tok_rep, num_segments=T
    )

    if mc.n_shared_experts:
        sp = params["shared"]
        g = jnp.einsum("td,df->tf", xf, sp["w_gate"].astype(dt))
        u = jnp.einsum("td,df->tf", xf, sp["w_up"].astype(dt))
        y = y + jnp.einsum(
            "tf,fd->td", jax.nn.silu(g) * u, sp["w_down"].astype(dt)
        )

    return y.reshape(B, S, D), aux
