"""GQA attention with RoPE, optional qk-norm / qkv-bias, and a KV cache.

Layouts (chosen for sharding):
  activations  x:      (B, S, D)            batch → ("pod","data")
  query        q:      (B, S, H, hd)        heads → "model"
  kv cache     k, v:   (B, M, KV, hd)       M (kv_seq) → "data" for
                                            long-context decode, else None

The decode path computes attention over the sharded cache with plain
einsums; reductions over the sharded M axis lower to small all-reduces
(flash-decode-style combining done by the partitioner).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .common import Initializer, apply_rope, dense_init, rms_norm, rope_angles

__all__ = [
    "init_attention", "attention_specs", "attention",
    "attention_decode_stacked",
    "AttnCache", "init_attn_cache", "chunked_causal_attention",
]

_NEG_INF = -1e30


class AttnCache(NamedTuple):
    k: jax.Array  # (B, M, KV, hd)
    v: jax.Array  # (B, M, KV, hd)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return AttnCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attention_specs(cfg: ModelConfig):
    """Logical-axis specs for :func:`init_attention` (no allocation)."""
    specs = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ("heads", "head_dim")
        specs["bk"] = ("kv_heads", "head_dim")
        specs["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        specs["q_norm"] = ("head_dim",)
        specs["k_norm"] = ("head_dim",)
    return specs


def init_attention(init: Initializer, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    params = {
        "wq": dense_init(init.next(), (d, h, hd)),
        "wk": dense_init(init.next(), (d, kv, hd)),
        "wv": dense_init(init.next(), (d, kv, hd)),
        "wo": dense_init(init.next(), (h, hd, d), in_axis=0),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h, hd), jnp.float32)
        params["bk"] = jnp.zeros((kv, hd), jnp.float32)
        params["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), jnp.float32)
        params["k_norm"] = jnp.ones((hd,), jnp.float32)
    return params, attention_specs(cfg)


def _project_qkv(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q: (B,S,H,hd), k: (B,M,KV,hd) → logits (B,S,KV,G,M) in f32.

    K stays in its storage dtype (bf16 cache) — the MXU accumulates in f32
    via ``preferred_element_type``; casting the cache to f32 would
    materialize a 2× copy of the whole KV cache per layer (the dominant
    decode traffic before perf iteration D1, see EXPERIMENTS.md §Perf).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum(
        "bskgh,bmkh->bskgm", qg, k, preferred_element_type=jnp.float32
    )
    return s * scale


def _gqa_out(p, v, dtype):
    """p: (B,S,KV,G,M) f32, v: (B,M,KV,hd) storage dtype → (B,S,H,hd)."""
    out = jnp.einsum(
        "bskgm,bmkh->bskgh", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    B, S, KV, G, hd = out.shape
    return out.reshape(B, S, KV * G, hd).astype(dtype)


def chunked_causal_attention(
    q: jax.Array,           # (B, S, H, hd)
    k: jax.Array,           # (B, M, KV, hd)
    v: jax.Array,           # (B, M, KV, hd)
    q_positions: jax.Array, # (B, S) absolute positions of queries
    kv_positions: jax.Array,  # (M,) absolute positions of keys
    scale: float,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention, doubly chunked (flash-style, pure JAX).

    Outer scan over query chunks, inner scan over KV chunks carrying the
    online-softmax state — peak live logits are O(q_chunk · kv_chunk) per
    (batch, head) instead of O(S · M), which is what lets the 4k-train and
    32k-prefill shapes lower with sane memory.  Differentiable (nested
    ``lax.scan``).  Compute is *not* causally pruned (future chunks are
    masked, not skipped) — the block-causal skip is a recorded perf
    iteration (EXPERIMENTS.md §Perf).
    """
    B, S, H, hd = q.shape
    M, KV = k.shape[1], k.shape[2]
    G = H // KV
    while S % q_chunk:
        q_chunk //= 2
    while M % kv_chunk:
        kv_chunk //= 2
    nq, nk = S // q_chunk, M // kv_chunk
    f32 = jnp.float32

    qg = (
        q.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    )                                                     # (nq,B,qc,KV,G,hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    # Block-causal skip (perf T4): iterate ONLY the lower-triangle (i, j)
    # chunk pairs as one flat scan — strictly-future blocks never execute,
    # removing ~44% of attention FLOPs and traffic at nq = nk = 8.  The
    # online-softmax state lives in full-size carries updated at slice i
    # (blocks for a given i arrive in increasing-j order — a valid online
    # softmax).  Masks are rebuilt from chunk indices and a local iota
    # (perf T1): only the diagonal block masks anything.
    assert nq == nk and S == M, "chunked path is self-attention only"
    i_list, j_list = [], []
    for i in range(nq):
        for j in range(i + 1):
            i_list.append(i)
            j_list.append(j)
    i_arr = jnp.asarray(i_list, jnp.int32)
    j_arr = jnp.asarray(j_list, jnp.int32)

    iq = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, kv_chunk), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, kv_chunk), 1)

    def body(carry, xs):
        m, l, acc = carry
        i, j = xs
        qb = jax.lax.dynamic_index_in_dim(qg, i, 0, keepdims=False)
        kk = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
        vv = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
        # bf16 operands, f32 accumulation (no f32 copies of K/V)
        s = jnp.einsum(
            "bqkgh,bckh->bqkgc", qb, kk, preferred_element_type=f32
        ) * scale
        mask = (j * kv_chunk + ik) <= (i * q_chunk + iq)  # (qc,c)
        mask = mask[None, :, None, None, :]
        s = jnp.where(mask, s, _NEG_INF)
        m_sl = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_sl = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_sl = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_cur = jnp.maximum(m_sl, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_sl - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        p = jnp.where(mask, p, 0.0)
        l_cur = l_sl * alpha + jnp.sum(p, axis=-1)
        a_cur = a_sl * alpha[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p.astype(vv.dtype), vv,
            preferred_element_type=f32,
        )
        m = jax.lax.dynamic_update_slice_in_dim(m, m_cur[None], i, 0)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_cur[None], i, 0)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_cur[None], i, 0)
        return (m, l, acc), None

    init = (
        jnp.full((nq, B, q_chunk, KV, G), _NEG_INF, f32),
        jnp.zeros((nq, B, q_chunk, KV, G), f32),
        jnp.zeros((nq, B, q_chunk, KV, G, hd), f32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (i_arr, j_arr))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (nq,B,qc,KV,G,hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out


# sequences longer than this use the chunked online-softmax path
_FULL_ATTN_MAX_SEQ = 1024


def attention(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[AttnCache] = None,
    cache_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[AttnCache]]:
    """Causal self-attention.

    Prefill / train: ``cache is None`` → full causal over ``x`` itself; if a
    cache object is wanted for subsequent decode, the caller writes k/v into
    it (see :func:`prefill_cache`).

    Decode: ``cache`` holds M past positions with ``cache_len`` valid; x has
    S new tokens (typically 1).  Returns updated cache.
    """
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = constrain(q, "batch", "seq", "heads", None)

    if cache is None:
        S = x.shape[1]
        if S > _FULL_ATTN_MAX_SEQ:
            kv_pos = jnp.arange(S, dtype=positions.dtype)
            out = chunked_causal_attention(
                q, k, v, positions, kv_pos, scale
            ).astype(x.dtype)
            y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
            return y, None
        s = _gqa_scores(q, k, scale)  # (B,S,KV,G,M=S)
        rows = positions[:, :, None]                       # (B,S,1)
        cols = positions[:, None, :]                       # (B,1,S)
        mask = (cols <= rows)[:, :, None, None, :]         # (B,S,1,1,M)
        s = jnp.where(mask, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = _gqa_out(p, v, x.dtype)
        new_cache = None
    else:
        # write new k/v at cache_len .. cache_len+S-1
        B, S = x.shape[:2]
        idx = cache_len
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0)
        )
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
        new_cache = AttnCache(ck, cv)
        M = ck.shape[1]
        s = _gqa_scores(q, ck, scale)  # (B,S,KV,G,M)
        cols = jnp.arange(M, dtype=jnp.int32)[None, :]     # (1,M)
        rows = positions                                    # (B,S)
        mask = (cols[:, None, :] <= rows[:, :, None])[:, :, None, None, :]
        s = jnp.where(mask, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = _gqa_out(p, cv, x.dtype)

    out = constrain(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


def attention_decode_readonly(
    params,
    cfg: ModelConfig,
    x: jax.Array,               # (B, 1, D)
    positions: jax.Array,       # (B, 1) == cache_len
    cache: AttnCache,           # ONE layer's slice (B, M, KV, hd), read-only
    cache_len: jax.Array,       # () int32 — tokens already in the cache
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode step that never writes the cache (perf iteration D4).

    The cache slice is consumed read-only; the current token's K/V are
    returned to the caller, which appends ALL layers' new tokens with one
    (L, B, 1, KV, hd) dynamic-update-slice after the layer scan.  This
    removes the per-layer whole-slice cache copies of the scan-ys
    formulation (53 GB/step → <1 MB/step of writes for qwen3@32k).

    Attention runs over [cache ; current token] via two-segment logits —
    no concatenated K/V is ever materialized.

    Returns (y, k_new, v_new).
    """
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = constrain(q, "batch", "seq", "heads", None)
    M = cache.k.shape[1]
    s_cache = _gqa_scores(q, cache.k, scale)           # (B,1,KV,G,M)
    cols = jnp.arange(M, dtype=jnp.int32)
    mask = (cols[None, :] < cache_len)[:, None, :][:, :, None, None, :]
    s_cache = jnp.where(mask, s_cache, _NEG_INF)
    s_self = _gqa_scores(q, k, scale)                  # (B,1,KV,G,1)
    # two-segment softmax without concatenation (keeps M evenly sharded —
    # see mla_decode_readonly)
    mm = jnp.maximum(jnp.max(s_cache, -1, keepdims=True), s_self)
    e_cache = jnp.exp(s_cache - mm)
    e_self = jnp.exp(s_self - mm)
    denom = jnp.sum(e_cache, -1, keepdims=True) + e_self
    p_cache = e_cache / denom
    p_self = e_self / denom
    out = jnp.einsum(
        "bskgm,bmkh->bskgh", p_cache.astype(cache.v.dtype), cache.v,
        preferred_element_type=jnp.float32,
    ) + jnp.einsum(
        "bskgm,bmkh->bskgh", p_self.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    B, S, KV, G, _ = out.shape
    out = out.reshape(B, S, KV * G, hd).astype(x.dtype)
    out = constrain(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, k.astype(cache.k.dtype), v.astype(cache.v.dtype)
