"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are produced through low-rank *latent* projections:

  c_q  = x W_dq           (d → q_lora_rank),  RMS-normed
  q    = c_q W_uq         → per head: [q_nope (128) | q_rope (64)]
  c_kv = x W_dkv          (d → kv_lora_rank), RMS-normed
  k    = [c_kv W_uk | k_rope]  — k_rope (64) is produced directly from x and
                                 shared across heads
  v    = c_kv W_uv        → per head 128

Only ``(c_kv, k_rope)`` is cached for decode — the MLA memory saving — and
the decode path uses the **absorbed** formulation: W_uk is folded into the
query (scores in latent space) and W_uv into the output projection, so
per-step work is O(S · kv_lora_rank) per head with no K/V materialization.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .common import Initializer, apply_rope, dense_init, rms_norm, rope_angles

__all__ = ["init_mla", "mla_specs", "mla", "MLACache", "init_mla_cache"]

_NEG_INF = -1e30


class MLACache(NamedTuple):
    c_kv: jax.Array   # (B, M, kv_lora_rank)
    k_rope: jax.Array  # (B, M, qk_rope_head_dim)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    )


def mla_specs(cfg: ModelConfig):
    """Logical-axis specs for :func:`init_mla` (no allocation)."""
    return {
        "w_dq": ("fsdp", None),
        "q_norm": (None,),
        "w_uq": ("fsdp", "heads", None),
        "w_dkv": ("fsdp", None),
        "kv_norm": (None,),
        "w_krope": ("fsdp", None),
        "w_uk": (None, "heads", None),
        "w_uv": (None, "heads", None),
        "wo": ("heads", None, "fsdp"),
    }


def init_mla(init: Initializer, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    params = {
        "w_dq": dense_init(init.next(), (d, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "w_uq": dense_init(init.next(), (m.q_lora_rank, h, qh)),
        "w_dkv": dense_init(init.next(), (d, m.kv_lora_rank)),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "w_krope": dense_init(init.next(), (d, m.qk_rope_head_dim)),
        "w_uk": dense_init(init.next(), (m.kv_lora_rank, h, m.qk_nope_head_dim)),
        "w_uv": dense_init(init.next(), (m.kv_lora_rank, h, m.v_head_dim)),
        "wo": dense_init(init.next(), (h, m.v_head_dim, d), in_axis=0),
    }
    return params, mla_specs(cfg)


def _latents(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    dt = x.dtype
    c_q = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt))
    c_q = rms_norm(params["q_norm"], c_q, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", c_q, params["w_uq"].astype(dt))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim :]
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    c_kv = rms_norm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_krope"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _mla_chunked_prefill(
    q_nope, q_rope, c_kv, k_rope, w_uk, w_uv, positions, scale,
    q_chunk: int = 512, kv_chunk: int = 512,
):
    """Doubly-chunked causal MLA prefill.

    Outer scan over KV chunks (each materializes its per-head K/V from the
    latent exactly once — no recompute, unlike an outer-Q loop), inner scan
    over query chunks updating slices of the full-size online-softmax state.
    Peak live logits are O(q_chunk · kv_chunk) per (batch, head).
    """
    B, S, H, dn = q_nope.shape
    M, r = c_kv.shape[1], c_kv.shape[2]
    dv = w_uv.shape[-1]
    f32 = jnp.float32
    while S % q_chunk:
        q_chunk //= 2
    while M % kv_chunk:
        kv_chunk //= 2
    nq, nk = S // q_chunk, M // kv_chunk

    qn = q_nope.reshape(B, nq, q_chunk, H, dn).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(B, nq, q_chunk, H, -1).transpose(1, 0, 2, 3, 4)
    ckv_c = c_kv.reshape(B, nk, kv_chunk, r).transpose(1, 0, 2, 3)
    krp_c = k_rope.reshape(B, nk, kv_chunk, -1).transpose(1, 0, 2, 3)

    # local-iota causal masks rebuilt per block (perf T1 — see attention.py)
    iq_ = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, kv_chunk), 0)
    ik_ = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, kv_chunk), 1)

    def kv_body(carry, kvs):
        ckv, krp, j = kvs
        cdt = ckv.dtype
        k_nope = jnp.einsum("bcr,rhk->bchk", ckv, w_uk.astype(cdt))
        v_c = jnp.einsum("bcr,rhk->bchk", ckv, w_uv.astype(cdt))

        def q_body(carry2, qs):
            m, l, acc = carry2
            i, qnb, qrb = qs
            s = jnp.einsum(
                "bqhk,bchk->bqhc", qnb, k_nope, preferred_element_type=f32
            )
            s = s + jnp.einsum(
                "bqhk,bck->bqhc", qrb, krp, preferred_element_type=f32
            )
            s = s * scale
            mask = (j * kv_chunk + ik_) <= (i * q_chunk + iq_)   # (qc,c)
            mask = mask[None, :, None, :]
            s = jnp.where(mask, s, _NEG_INF)
            off = i * q_chunk
            m_sl = jax.lax.dynamic_slice(m, (0, off, 0), (B, q_chunk, H))
            l_sl = jax.lax.dynamic_slice(l, (0, off, 0), (B, q_chunk, H))
            a_sl = jax.lax.dynamic_slice(acc, (0, off, 0, 0), (B, q_chunk, H, dv))
            m_cur = jnp.maximum(m_sl, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_sl - m_cur)
            p = jnp.exp(s - m_cur[..., None])
            p = jnp.where(mask, p, 0.0)
            l_new = l_sl * alpha + jnp.sum(p, axis=-1)
            a_new = a_sl * alpha[..., None] + jnp.einsum(
                "bqhc,bchk->bqhk", p.astype(v_c.dtype), v_c,
                preferred_element_type=f32,
            )
            m = jax.lax.dynamic_update_slice(m, m_cur, (0, off, 0))
            l = jax.lax.dynamic_update_slice(l, l_new, (0, off, 0))
            acc = jax.lax.dynamic_update_slice(acc, a_new, (0, off, 0, 0))
            return (m, l, acc), None

        carry, _ = jax.lax.scan(
            q_body, carry, (jnp.arange(nq, dtype=jnp.int32), qn, qr)
        )
        return carry, None

    init = (
        jnp.full((B, S, H), _NEG_INF, f32),
        jnp.zeros((B, S, H), f32),
        jnp.zeros((B, S, H, dv), f32),
    )
    (m, l, acc), _ = jax.lax.scan(
        kv_body, init, (ckv_c, krp_c, jnp.arange(nk, dtype=jnp.int32))
    )
    return acc / jnp.maximum(l, 1e-30)[..., None]


# sequences longer than this use the chunked online-softmax prefill path
_FULL_ATTN_MAX_SEQ = 1024


def mla(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[MLACache] = None,
    cache_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[MLACache]]:
    m = cfg.mla
    h = cfg.n_heads
    dt = x.dtype
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _latents(params, cfg, x, positions)
    q_nope = constrain(q_nope, "batch", "seq", "heads", None)

    if cache is None:
        S = x.shape[1]
        if S > _FULL_ATTN_MAX_SEQ:
            out = _mla_chunked_prefill(
                q_nope, q_rope, c_kv, k_rope,
                params["w_uk"], params["w_uv"], positions, scale,
            ).astype(dt)
            out = constrain(out, "batch", "seq", "heads", None)
            return (
                jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)),
                None,
            )
        # prefill/train (short sequences): materialize per-head K and V
        k_nope = jnp.einsum("bmr,rhk->bmhk", c_kv, params["w_uk"].astype(dt))
        v = jnp.einsum("bmr,rhk->bmhk", c_kv, params["w_uv"].astype(dt))
        s = jnp.einsum(
            "bshk,bmhk->bshm", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32)
        )
        s = s + jnp.einsum(
            "bshk,bmk->bshm", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
        s = s * scale
        rows = positions[:, :, None]                    # (B,S,1)
        cols = positions[:, None, :]                    # (B,1,M)
        s = jnp.where((cols <= rows)[:, :, None, :], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bshm,bmhk->bshk", p, v.astype(jnp.float32)).astype(dt)
        new_cache = None
    else:
        # decode: absorbed formulation over the latent cache
        idx = cache_len
        ckv = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, idx, 0)
        )
        krp = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, idx, 0)
        )
        ckv = constrain(ckv, "batch", "kv_seq", None)
        krp = constrain(krp, "batch", "kv_seq", None)
        new_cache = MLACache(ckv, krp)
        M = ckv.shape[1]
        # absorb W_uk into q: (B,S,H,nope) × (r,H,nope) → (B,S,H,r)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
        # latent cache stays in storage dtype; MXU accumulates in f32
        # (perf iteration D1: no f32 copy of the cache)
        s = jnp.einsum(
            "bshr,bmr->bshm", q_lat, ckv, preferred_element_type=jnp.float32
        )
        s = s + jnp.einsum(
            "bshk,bmk->bshm", q_rope, krp, preferred_element_type=jnp.float32
        )
        s = s * scale
        cols = jnp.arange(M, dtype=jnp.int32)[None, None, None, :]
        mask = cols <= positions[:, :, None, None]
        s = jnp.where(mask, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # attention output in latent space, then absorb W_uv
        o_lat = jnp.einsum(
            "bshm,bmr->bshr", p.astype(ckv.dtype), ckv,
            preferred_element_type=jnp.float32,
        )
        out = jnp.einsum(
            "bshr,rhk->bshk", o_lat, params["w_uv"].astype(jnp.float32)
        ).astype(dt)

    out = constrain(out, "batch", "seq", "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)), new_cache


def mla_decode_readonly(
    params,
    cfg: ModelConfig,
    x: jax.Array,            # (B, 1, D)
    positions: jax.Array,    # (B, 1) == cache_len
    cache: MLACache,         # ONE layer's latent slice (B, M, ·), read-only
    cache_len: jax.Array,    # () int32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-form decode, cache read-only (perf iteration D4 — see
    attention.attention_decode_readonly).  Returns (y, c_kv_new, k_rope_new)."""
    m = cfg.mla
    dt = x.dtype
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _latents(params, cfg, x, positions)
    q_nope = constrain(q_nope, "batch", "seq", "heads", None)
    M = cache.c_kv.shape[1]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
    s_cache = jnp.einsum("bshr,bmr->bshm", q_lat, cache.c_kv,
                         preferred_element_type=jnp.float32)
    s_cache = s_cache + jnp.einsum("bshk,bmk->bshm", q_rope, cache.k_rope,
                                   preferred_element_type=jnp.float32)
    cols = jnp.arange(M, dtype=jnp.int32)
    mask = (cols[None, :] < cache_len)[:, None, None, :]
    s_cache = jnp.where(mask, s_cache * scale, _NEG_INF)
    s_self = (
        jnp.einsum("bshr,bmr->bshm", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bshk,bmk->bshm", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * scale                                              # (B,1,H,1)
    # two-segment softmax WITHOUT concatenation: an (M+1)-length logits
    # tensor breaks the even kv_seq sharding of M and forces a per-layer
    # reshard (observed 1.7× regression on deepseek-v3 decode, multi-pod)
    mm = jnp.maximum(jnp.max(s_cache, -1, keepdims=True), s_self)
    e_cache = jnp.exp(s_cache - mm)
    e_self = jnp.exp(s_self - mm)
    denom = jnp.sum(e_cache, -1, keepdims=True) + e_self
    p_cache = e_cache / denom
    p_self = e_self / denom
    o_lat = jnp.einsum(
        "bshm,bmr->bshr", p_cache.astype(cache.c_kv.dtype), cache.c_kv,
        preferred_element_type=jnp.float32,
    ) + jnp.einsum(
        "bshm,bmr->bshr", p_self.astype(c_kv.dtype), c_kv,
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum(
        "bshr,rhk->bshk", o_lat, params["w_uv"].astype(jnp.float32)
    ).astype(dt)
    out = constrain(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, c_kv.astype(cache.c_kv.dtype), k_rope.astype(cache.k_rope.dtype)
