"""Mamba2 block (state-space duality / SSD), chunked-parallel + recurrent.

Prefill/train use the chunked SSD algorithm (quadratic within a chunk,
linear across chunks); decode uses the O(1) recurrent update.  The layer
follows the Mamba2 reference: fused input projection → short causal
depthwise conv on (x, B, C) → SSD core → gated RMSNorm → output projection.

Head layout: ``d_inner = expand · d_model``; ``n_heads = d_inner / head_dim``;
state per head is ``(head_dim, d_state)``.  TP shards the head dimension.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .common import Initializer, dense_init

__all__ = [
    "init_mamba2", "mamba2_specs", "mamba2",
    "SSMCache", "init_ssm_cache", "mamba2_decode",
]


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_dim) rolling conv input window
    state: jax.Array  # (B, H, P, N) SSD state


def _dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    n_heads = d_inner // sc.head_dim
    conv_dim = d_inner + 2 * sc.n_groups * sc.d_state
    return d_inner, n_heads, conv_dim


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    sc = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, sc.conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((batch, n_heads, sc.head_dim, sc.d_state), jnp.float32),
    )


def mamba2_specs(cfg: ModelConfig):
    """Logical-axis specs for :func:`init_mamba2` (no allocation)."""
    return {
        "w_in": ("fsdp", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "A_log": ("heads",),
        "D": ("heads",),
        "dt_bias": ("heads",),
        "norm_w": ("ff",),
        "w_out": ("ff", "fsdp"),
    }


def init_mamba2(init: Initializer, cfg: ModelConfig):
    sc = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = _dims(cfg)
    proj_out = 2 * d_inner + 2 * sc.n_groups * sc.d_state + n_heads
    params = {
        "w_in": dense_init(init.next(), (d, proj_out)),
        "conv_w": 0.1 * jax.random.normal(
            init.next(), (sc.conv_width, conv_dim), jnp.float32
        ),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(init.next(), (n_heads,), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jax.random.uniform(init.next(), (n_heads,), jnp.float32, 1e-3, 0.1)
            )
        ),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(init.next(), (d_inner, d)),
    }
    return params, mamba2_specs(cfg)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    sc = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    gn = sc.n_groups * sc.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt  # xbc = [x | B | C] pre-conv


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq.  x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x[..., k]."""
    T = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD core (chunked scan).

    x: (b, s, h, p); dt: (b, s, h); A: (h,) negative decay rates;
    B, C: (b, s, g, n).  Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)   # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]              # (b,nc,q,h) negative
    dA_cs = jnp.cumsum(dA, axis=2)                 # within-chunk cumsum

    # 1. within-chunk (diagonal blocks): quadratic attention-like form
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))           # (b,nc,h,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)        # (b,nc,h,q,k)
    y_diag = jnp.einsum(
        "bchqk,bchqk,bckh,bckhp->bcqhp",
        scores, L, dtc, xc,
    )

    # 2. chunk states: decayed contribution of each chunk's inputs
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b,nc,q,h)
    states = jnp.einsum(
        "bcqhn,bcqh,bcqh,bcqhp->bchpn", Bh, decay_states, dtc, xc
    )                                                        # (b,nc,h,p,n)

    # 3. inter-chunk recurrence (scan over chunks, O(nc))
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (b,nc,h)

    def scan_fn(carry, inp):
        st_prev = carry                                      # (b,h,p,n)
        st_c, dec_c = inp                                    # (b,h,p,n), (b,h)
        new = st_c + dec_c[:, :, None, None] * st_prev
        return new, st_prev

    init = jnp.zeros((b, h, p, n), x.dtype)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,nc,h,p,n)

    # 4. contribution of previous-chunk states to outputs
    state_decay = jnp.exp(dA_cs)                             # (b,nc,q,h)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def mamba2(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    cache: Optional[SSMCache] = None,
) -> Tuple[jax.Array, Optional[SSMCache]]:
    """Full-sequence forward (train / prefill).  x: (B, S, D)."""
    sc = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    gn = sc.n_groups * sc.d_state
    dt_ = x.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dt_))
    z, xbc, dtr = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xi, B, C = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)

    b, s = x.shape[:2]
    xi = xi.reshape(b, s, n_heads, sc.head_dim)
    B = B.reshape(b, s, sc.n_groups, sc.d_state)
    C = C.reshape(b, s, sc.n_groups, sc.d_state)
    dt = jax.nn.softplus(
        dtr.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )                                                         # (b,s,h)
    A = -jnp.exp(params["A_log"])                             # (h,) negative

    xi = constrain(xi, "batch", "seq", "heads", None)
    y, final_state = _ssd_chunked(
        xi.astype(jnp.float32), dt, A, B.astype(jnp.float32),
        C.astype(jnp.float32), min(sc.chunk_size, s),
    )
    y = y + params["D"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(dt_)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_w"]).astype(dt_)

    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    new_cache = None
    if cache is not None:
        w = sc.conv_width
        xbc_raw = _split_proj(cfg, zxbcdt)[1]
        conv_tail = xbc_raw[:, -(w - 1):, :] if s >= w - 1 else jnp.concatenate(
            [cache.conv[:, s:, :], xbc_raw], axis=1
        )
        new_cache = SSMCache(conv=conv_tail.astype(cache.conv.dtype),
                             state=final_state.astype(jnp.float32))
    return out, new_cache


def mamba2_decode(
    params, cfg: ModelConfig, x: jax.Array, cache: SSMCache
) -> Tuple[jax.Array, SSMCache]:
    """Single-token recurrent step.  x: (B, 1, D) → (B, 1, D)."""
    sc = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    gn = sc.n_groups * sc.d_state
    dt_ = x.dtype
    b = x.shape[0]

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dt_))
    z, xbc, dtr = _split_proj(cfg, zxbcdt)                     # (b,1,·)

    # rolling conv window
    win = jnp.concatenate([cache.conv, xbc], axis=1)           # (b,W,conv_dim)
    conv_out = (
        jnp.einsum("bwc,wc->bc", win.astype(jnp.float32), params["conv_w"])
        + params["conv_b"]
    )
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(dt_)       # (b,1,·)
    xi, B, C = jnp.split(xbc1, [d_inner, d_inner + gn], axis=-1)
    xi = xi.reshape(b, n_heads, sc.head_dim).astype(jnp.float32)
    B1 = B.reshape(b, sc.n_groups, sc.d_state).astype(jnp.float32)
    C1 = C.reshape(b, sc.n_groups, sc.d_state).astype(jnp.float32)
    rep = n_heads // sc.n_groups
    Bh = jnp.repeat(B1, rep, axis=1)                           # (b,h,n)
    Ch = jnp.repeat(C1, rep, axis=1)
    dt1 = jax.nn.softplus(
        dtr[:, 0, :].astype(jnp.float32) + params["dt_bias"][None, :]
    )                                                          # (b,h)
    A = -jnp.exp(params["A_log"])

    decay = jnp.exp(dt1 * A[None, :])                          # (b,h)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bh, xi)
    state = cache.state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state)
    y = y + params["D"][None, :, None] * xi
    y = y.reshape(b, 1, d_inner).astype(dt_)

    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_w"]).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    new_cache = SSMCache(conv=win[:, 1:, :], state=state)
    return out, new_cache
