"""SwiGLU MLP (llama/qwen convention: gate ⊙ silu, no biases)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import Initializer, dense_init

__all__ = ["init_mlp", "mlp_specs", "mlp"]


def mlp_specs():
    """Logical-axis specs for :func:`init_mlp` (no allocation)."""
    return {
        "w_gate": ("fsdp", "ff"),
        "w_up": ("fsdp", "ff"),
        "w_down": ("ff", "fsdp"),
    }


def init_mlp(init: Initializer, d_model: int, d_ff: int):
    params = {
        "w_gate": dense_init(init.next(), (d_model, d_ff)),
        "w_up": dense_init(init.next(), (d_model, d_ff)),
        "w_down": dense_init(init.next(), (d_ff, d_model)),
    }
    return params, mlp_specs()


def mlp(params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))
