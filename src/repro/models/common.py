"""Shared model components: norms, RoPE, initializers, module conventions.

Module convention (no external NN library):

  * ``init_<thing>(key, ...) -> (params, specs)`` — ``params`` is a nested
    dict of arrays; ``specs`` mirrors it with tuples of *logical* axis names
    per leaf (see :mod:`repro.distributed.sharding`).
  * ``<thing>(params, x, ...)`` — pure apply function.

All parameters are created in float32; the train/serve steps cast to the
compute dtype (bf16 by default) at the boundary ("params in fp32, compute
in bf16" mixed precision).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Initializer",
    "dense_init",
    "embed_init",
    "rms_norm",
    "init_rms_norm",
    "rope_angles",
    "apply_rope",
    "split_key",
]


def split_key(key, n):
    return jax.random.split(key, n)


def dense_init(key, shape, in_axis: int = 0) -> jax.Array:
    """Truncated-normal scaled by fan-in (LeCun/TN init used by most LMs)."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


def embed_init(key, shape, std: float = 0.02) -> jax.Array:
    return std * jax.random.normal(key, shape, jnp.float32)


class Initializer:
    """Sequential key splitter: ``init.next()`` hands out fresh keys."""

    def __init__(self, key):
        self._key = key

    def next(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def init_rms_norm(d: int):
    return jnp.ones((d,), jnp.float32), ("d_model",)


def rms_norm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(dtype)


def rope_angles(
    positions: jax.Array, dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embeddings.  positions: (..., S)."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) — llama convention.

    x: (..., S, H, dim); cos/sin: (..., S, dim/2) broadcast over heads.
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)
