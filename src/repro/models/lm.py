"""Composable causal-LM assembly for every assigned architecture family.

One :class:`ModelConfig` fully determines the network.  Layers are grouped
into *scan groups* — maximal runs of identically-structured blocks whose
parameters are stacked along a leading ``layers`` axis and executed with
``jax.lax.scan`` (essential for compile time at 60+ layers):

  ================  =============================================
  family            scan groups
  ================  =============================================
  dense / vlm /     [("attn_dense", L)]
  audio
  moe               [("attn_dense", n_dense_layers)?, ("attn_moe", rest)]
  hybrid (zamba2)   [("hybrid", L / shared_every)] — each scanned unit is
                    ``shared_every`` Mamba2 layers followed by one
                    invocation of the *shared* attention+MLP block (weights
                    outside the scan, reused by every invocation)
  ssm+xlstm         [("xlstm", L / slstm_every)] — each unit is
                    ``slstm_every − 1`` mLSTM blocks + 1 sLSTM block
  ================  =============================================

Two entry points mirror the run shapes:

  * :func:`lm_forward` — full-sequence forward (train / prefill).  Returns
    logits (+ aux losses; + caches primed for decode when requested).
  * :func:`lm_decode_step` — one-token step against the caches.

``init_lm(key, cfg)`` allocates parameters; ``lm_specs(cfg)`` returns the
matching logical-sharding-spec tree *without any allocation* (the dry-run
combines it with ``jax.eval_shape(init_lm, ...)`` so full-size models are
never materialized on the host).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .attention import (
    AttnCache, attention, attention_decode_readonly, attention_specs,
    init_attention, init_attn_cache,
)
from .common import Initializer, embed_init, rms_norm
from .mla import (
    MLACache, init_mla, init_mla_cache, mla, mla_decode_readonly, mla_specs,
)
from .mlp import init_mlp, mlp, mlp_specs
from .moe import init_moe, moe, moe_specs
from .ssm import (
    SSMCache, init_mamba2, init_ssm_cache, mamba2, mamba2_decode, mamba2_specs,
)
from .xlstm import (
    MLSTMCache, SLSTMCache,
    init_mlstm_block, init_mlstm_cache, init_slstm_block, init_slstm_cache,
    mlstm_block, mlstm_specs, slstm_block, slstm_specs,
)

__all__ = [
    "GroupPlan", "make_plan", "init_lm", "lm_specs",
    "lm_forward", "lm_decode_step", "mtp_logits",
    "init_lm_caches", "lm_cache_specs", "param_count",
]


class GroupPlan(NamedTuple):
    kind: str    # attn_dense | attn_moe | hybrid | xlstm
    count: int   # number of scanned units


def make_plan(cfg: ModelConfig) -> List[GroupPlan]:
    if cfg.xlstm is not None:
        k = cfg.xlstm.slstm_every
        assert cfg.n_layers % k == 0, (cfg.n_layers, k)
        return [GroupPlan("xlstm", cfg.n_layers // k)]
    if cfg.hybrid is not None:
        k = cfg.hybrid.shared_every
        assert cfg.n_layers % k == 0, (cfg.n_layers, k)
        return [GroupPlan("hybrid", cfg.n_layers // k)]
    if cfg.moe is not None:
        nd = cfg.moe.n_dense_layers
        plan = []
        if nd:
            plan.append(GroupPlan("attn_dense", nd))
        plan.append(GroupPlan("attn_moe", cfg.n_layers - nd))
        return plan
    return [GroupPlan("attn_dense", cfg.n_layers)]


# --------------------------------------------------------------------- #
# per-unit init / specs
# --------------------------------------------------------------------- #
def _attn_kind(cfg: ModelConfig) -> str:
    return "mla" if cfg.mla is not None else "gqa"


def _init_attn_block(init: Initializer, cfg: ModelConfig, use_moe: bool):
    """One transformer block: norm → attn → norm → mlp/moe."""
    attn_p, _ = (init_mla if _attn_kind(cfg) == "mla" else init_attention)(init, cfg)
    params = {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn_p,
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if use_moe:
        params["moe"], _ = init_moe(init, cfg)
    else:
        params["mlp"], _ = init_mlp(init, cfg.d_model, _dense_ff(cfg))
    return params


def _dense_ff(cfg: ModelConfig) -> int:
    """FFN width for *dense* blocks.  In MoE configs ``cfg.d_ff`` is the
    per-expert width; the leading dense layers use ``moe.d_ff_dense``."""
    if cfg.moe is not None and cfg.moe.d_ff_dense:
        return cfg.moe.d_ff_dense
    return cfg.d_ff


def _attn_block_specs(cfg: ModelConfig, use_moe: bool):
    specs: Dict[str, Any] = {
        "norm1": ("d_model",),
        "attn": (mla_specs if _attn_kind(cfg) == "mla" else attention_specs)(cfg),
        "norm2": ("d_model",),
    }
    if use_moe:
        specs["moe"] = moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs()
    return specs


def _init_hybrid_unit(init: Initializer, cfg: ModelConfig):
    """``shared_every`` stacked Mamba2 layers (shared block lives outside)."""
    k = cfg.hybrid.shared_every
    layers = [_init_mamba_layer(init, cfg) for _ in range(k)]
    return _stack(layers)


def _init_mamba_layer(init: Initializer, cfg: ModelConfig):
    p, _ = init_mamba2(init, cfg)
    return {"norm": jnp.ones((cfg.d_model,), jnp.float32), "mamba": p}


def _mamba_layer_specs(cfg: ModelConfig):
    return {"norm": ("d_model",), "mamba": mamba2_specs(cfg)}


def _init_xlstm_unit(init: Initializer, cfg: ModelConfig):
    k = cfg.xlstm.slstm_every
    mls = [init_mlstm_block(init, cfg)[0] for _ in range(k - 1)]
    sls = init_slstm_block(init, cfg)[0]
    return {"mlstm": _stack(mls), "slstm": sls}


def _xlstm_unit_specs(cfg: ModelConfig):
    return {
        "mlstm": _prepend_axis(mlstm_specs(cfg)),
        "slstm": slstm_specs(cfg),
    }


def _stack(trees: List[Any]):
    if len(trees) == 1:
        return jax.tree.map(lambda x: x[None], trees[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _prepend_axis(spec_tree):
    """Prepend the (replicated) stacked-layers axis to every leaf spec."""
    return jax.tree.map(
        lambda s: ("layers",) + tuple(s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, tuple),
    )


# --------------------------------------------------------------------- #
# top-level init / specs
# --------------------------------------------------------------------- #
def init_lm(key: jax.Array, cfg: ModelConfig):
    """Allocate all parameters (float32 masters)."""
    init = Initializer(key)
    plan = make_plan(cfg)
    groups = []
    for g in plan:
        if g.kind in ("attn_dense", "attn_moe"):
            use_moe = g.kind == "attn_moe"
            units = [_init_attn_block(init, cfg, use_moe) for _ in range(g.count)]
            groups.append({"stacked": _stack(units)})
        elif g.kind == "hybrid":
            units = [_init_hybrid_unit(init, cfg) for _ in range(g.count)]
            groups.append(
                {
                    "stacked": _stack(units),
                    "shared": _init_attn_block(init, cfg, use_moe=False),
                }
            )
        elif g.kind == "xlstm":
            units = [_init_xlstm_unit(init, cfg) for _ in range(g.count)]
            groups.append({"stacked": _stack(units)})
        else:  # pragma: no cover
            raise ValueError(g.kind)

    params: Dict[str, Any] = {
        "embed": embed_init(init.next(), (cfg.vocab_size, cfg.d_model)),
        "groups": groups,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(init.next(), (cfg.d_model, cfg.vocab_size)) * (
            cfg.d_model ** -0.5
        )
    if cfg.mtp:
        params["mtp"] = {
            "proj": embed_init(init.next(), (2 * cfg.d_model, cfg.d_model))
            * ((2 * cfg.d_model) ** -0.5),
            "block": _init_attn_block(init, cfg, use_moe=False),
            "norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def lm_specs(cfg: ModelConfig):
    """Logical-sharding spec tree mirroring :func:`init_lm` — no allocation."""
    plan = make_plan(cfg)
    groups = []
    for g in plan:
        if g.kind in ("attn_dense", "attn_moe"):
            unit = _attn_block_specs(cfg, g.kind == "attn_moe")
            groups.append({"stacked": _prepend_axis(unit)})
        elif g.kind == "hybrid":
            unit = _prepend_axis(_mamba_layer_specs(cfg))  # inner (se) axis
            groups.append(
                {
                    "stacked": _prepend_axis(unit),        # outer (groups) axis
                    "shared": _attn_block_specs(cfg, use_moe=False),
                }
            )
        elif g.kind == "xlstm":
            groups.append({"stacked": _prepend_axis(_xlstm_unit_specs(cfg))})
        else:  # pragma: no cover
            raise ValueError(g.kind)
    specs: Dict[str, Any] = {
        "embed": ("vocab", "fsdp"),
        "groups": groups,
        "final_norm": ("d_model",),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ("fsdp", "vocab")
    if cfg.mtp:
        specs["mtp"] = {
            "proj": ("fsdp", None),
            "block": _attn_block_specs(cfg, use_moe=False),
            "norm": ("d_model",),
        }
    return specs


def param_count(cfg: ModelConfig) -> int:
    import numpy as _np

    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.key(0))
    return sum(int(_np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# --------------------------------------------------------------------- #
# caches
# --------------------------------------------------------------------- #
def init_lm_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode caches, structured parallel to ``params['groups']``."""
    plan = make_plan(cfg)
    caches = []
    for g in plan:
        if g.kind in ("attn_dense", "attn_moe"):
            one = (
                init_mla_cache(cfg, batch, max_len, dtype)
                if _attn_kind(cfg) == "mla"
                else init_attn_cache(cfg, batch, max_len, dtype)
            )
            caches.append(jax.tree.map(lambda x: _tile(x, g.count), one))
        elif g.kind == "hybrid":
            m = init_ssm_cache(cfg, batch, dtype)
            caches.append(
                {
                    "mamba": jax.tree.map(
                        lambda x: _tile(_tile(x, cfg.hybrid.shared_every), g.count), m
                    ),
                    "attn": jax.tree.map(
                        lambda x: _tile(x, g.count),
                        init_attn_cache(cfg, batch, max_len, dtype),
                    ),
                }
            )
        elif g.kind == "xlstm":
            k = cfg.xlstm.slstm_every
            ml = init_mlstm_cache(cfg, batch, dtype)
            sl = init_slstm_cache(cfg, batch, dtype)
            caches.append(
                {
                    "mlstm": jax.tree.map(
                        lambda x: _tile(_tile(x, k - 1), g.count), ml
                    ),
                    "slstm": jax.tree.map(lambda x: _tile(x, g.count), sl),
                }
            )
    return caches


def _tile(x: jax.Array, n: int) -> jax.Array:
    return jnp.broadcast_to(x[None], (n,) + x.shape)


def lm_cache_specs(cfg: ModelConfig, shard_kv_seq: bool = False):
    """Logical-axis spec tree for :func:`init_lm_caches`.

    KV caches are sharded batch-first; ``shard_kv_seq=True`` additionally
    shards the sequence axis of attention KV caches over ``data`` (SP for
    long-context decode, where batch is too small to fill the mesh).
    """
    kv_seq = "kv_seq" if shard_kv_seq else None
    plan = make_plan(cfg)

    def attn_cache_spec():
        if _attn_kind(cfg) == "mla":
            return MLACache(
                c_kv=("layers", "batch", kv_seq, None),
                k_rope=("layers", "batch", kv_seq, None),
            )
        return AttnCache(
            k=("layers", "batch", kv_seq, "kv_heads", None),
            v=("layers", "batch", kv_seq, "kv_heads", None),
        )

    specs = []
    for g in plan:
        if g.kind in ("attn_dense", "attn_moe"):
            specs.append(attn_cache_spec())
        elif g.kind == "hybrid":
            specs.append(
                {
                    "mamba": SSMCache(
                        conv=("layers", "layers", "batch", None, "ff"),
                        state=("layers", "layers", "batch", "heads", None, None),
                    ),
                    "attn": attn_cache_spec(),
                }
            )
        elif g.kind == "xlstm":
            specs.append(
                {
                    "mlstm": MLSTMCache(
                        C=("layers", "layers", "batch", "heads", None, None),
                        n=("layers", "layers", "batch", "heads", None),
                        m=("layers", "layers", "batch", "heads"),
                        conv=("layers", "layers", "batch", None, "ff"),
                    ),
                    "slstm": SLSTMCache(
                        c=("layers", "batch", "heads", None),
                        n=("layers", "batch", "heads", None),
                        h=("layers", "batch", "heads", None),
                        m=("layers", "batch", "heads", None),
                        conv=("layers", "batch", None, "d_model"),
                    ),
                }
            )
    return specs


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def _attn_block_decode(params, cfg: ModelConfig, x, positions, cache,
                       cache_len, use_moe: bool):
    """Decode-step transformer block; the cache slice is READ-ONLY.

    Returns (x, (new_token_a, new_token_b)) — the layer's K/V (or latent)
    for the current token, appended by the caller with one stacked DUS
    after the layer scan (perf iteration D4).  MoE always runs dropless
    here (serving correctness — see moe()).
    """
    attn_fn = (
        mla_decode_readonly if _attn_kind(cfg) == "mla"
        else attention_decode_readonly
    )
    h = rms_norm(params["norm1"], x, cfg.norm_eps)
    a, n1, n2 = attn_fn(
        params["attn"], cfg, h, positions, cache, cache_len
    )
    x = x + a
    h = rms_norm(params["norm2"], x, cfg.norm_eps)
    if use_moe:
        y, _ = moe(params["moe"], cfg, h, dropless=True)
    else:
        y = mlp(params["mlp"], h)
    return x + y, (n1, n2)


def _append_tokens(cache, news, cache_len):
    """One stacked (L, B, 1, ·) DUS per cache leaf — the only cache write
    of a decode step."""
    zero = jnp.int32(0)
    if isinstance(cache, MLACache):
        return MLACache(
            c_kv=jax.lax.dynamic_update_slice(
                cache.c_kv, news[0], (zero, zero, cache_len, zero)
            ),
            k_rope=jax.lax.dynamic_update_slice(
                cache.k_rope, news[1], (zero, zero, cache_len, zero)
            ),
        )
    return AttnCache(
        k=jax.lax.dynamic_update_slice(
            cache.k, news[0], (zero, zero, cache_len, zero, zero)
        ),
        v=jax.lax.dynamic_update_slice(
            cache.v, news[1], (zero, zero, cache_len, zero, zero)
        ),
    )


def _attn_block_apply(params, cfg: ModelConfig, x, positions, cache, cache_len,
                      use_moe: bool, moe_dropless: bool = False):
    """One transformer block.  Returns (x, new_cache, aux)."""
    attn_fn = mla if _attn_kind(cfg) == "mla" else attention
    h = rms_norm(params["norm1"], x, cfg.norm_eps)
    a, new_cache = attn_fn(params["attn"], cfg, h, positions, cache, cache_len)
    x = x + a
    h = rms_norm(params["norm2"], x, cfg.norm_eps)
    if use_moe:
        y, aux = moe(params["moe"], cfg, h, dropless=moe_dropless)
    else:
        y, aux = mlp(params["mlp"], h), jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux


def _scan_group(body, x, stacked_params, stacked_caches, remat: bool):
    """Scan ``body(x, p, c) → (x, new_c, aux)`` over the stacked layer axis.

    ``stacked_caches is None`` threads ``c=None`` (train / cache-less
    prefill) and returns ``None`` caches.
    """
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if stacked_caches is None:
        def f(carry, p):
            x, aux = carry
            x, _, a = body(x, p, None)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            f, (x, jnp.zeros((), jnp.float32)), stacked_params
        )
        return x, aux, None

    def f(carry, xs):
        x, aux = carry
        p, c = xs
        x, new_c, a = body(x, p, c)
        return (x, aux + a), new_c

    (x, aux), new_caches = jax.lax.scan(
        f, (x, jnp.zeros((), jnp.float32)), (stacked_params, stacked_caches)
    )
    return x, aux, new_caches


def lm_forward(
    params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,     # (B, S) int32
    embeds: Optional[jax.Array] = None,     # (B, S, D) — modality-stub input
    positions: Optional[jax.Array] = None,  # (B, S)
    caches=None,                            # from init_lm_caches (prime-for-decode)
    cache_len: Optional[jax.Array] = None,  # () int32 — write offset
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
    return_hidden: bool = False,
    moe_dropless: bool = False,
):
    """Full-sequence forward (train / prefill).

    Returns ``(logits, aux, new_caches[, hidden])``: ``aux`` is the summed
    MoE load-balance loss; ``new_caches`` is None unless ``caches`` given.
    """
    if embeds is not None:
        x = embeds.astype(compute_dtype)
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = params["embed"].astype(compute_dtype)[tokens]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", "d_model")

    plan = make_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Optional[list] = [] if caches is not None else None

    for gi, g in enumerate(plan):
        gp = params["groups"][gi]
        gc = caches[gi] if caches is not None else None
        if g.kind in ("attn_dense", "attn_moe"):
            use_moe = g.kind == "attn_moe"

            def body(x, p, c, _use_moe=use_moe):
                return _attn_block_apply(
                    p, cfg, x, positions, c, cache_len, _use_moe,
                    moe_dropless=moe_dropless,
                )

            x, aux, nc = _scan_group(
                body, x, gp["stacked"],
                gc,
                remat,
            )
            aux_total += aux
        elif g.kind == "hybrid":
            shared_p = gp["shared"]

            def body(x, p, c, _sp=shared_p):
                mamba_p = p
                mc = c["mamba"] if c is not None else None

                def inner(x, ip, ic):
                    h = rms_norm(ip["norm"], x, cfg.norm_eps)
                    y, nc = mamba2(ip["mamba"], cfg, h, ic)
                    return x + y, nc, jnp.zeros((), jnp.float32)

                x, _, n_mc = _scan_group(
                    inner, x, mamba_p,
                    mc,
                    remat=False,
                )
                ac = c["attn"] if c is not None else None
                x, n_ac, _ = _attn_block_apply(
                    _sp, cfg, x, positions, ac, cache_len, use_moe=False
                )
                out_c = (
                    {"mamba": n_mc, "attn": n_ac} if c is not None else None
                )
                return x, out_c, jnp.zeros((), jnp.float32)

            x, _, nc = _scan_group(
                body, x, gp["stacked"],
                gc,
                remat,
            )
        elif g.kind == "xlstm":
            k = cfg.xlstm.slstm_every

            def body(x, p, c):
                mcs = c["mlstm"] if c is not None else None

                def inner(x, ip, ic):
                    y, nc = mlstm_block(ip, cfg, x, ic)
                    return y, nc, jnp.zeros((), jnp.float32)

                x, _, n_ml = _scan_group(
                    inner, x, p["mlstm"],
                    mcs,
                    remat=False,
                )
                sc = c["slstm"] if c is not None else None
                x, n_sl = slstm_block(p["slstm"], cfg, x, sc)
                out_c = {"mlstm": n_ml, "slstm": n_sl} if c is not None else None
                return x, out_c, jnp.zeros((), jnp.float32)

            x, _, nc = _scan_group(
                body, x, gp["stacked"],
                gc,
                remat,
            )
        if new_caches is not None:
            new_caches.append(nc)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["head"]
    ).astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, "batch", "seq", "vocab")
    if return_hidden:
        return logits, aux_total, new_caches, x
    return logits, aux_total, new_caches


def lm_decode_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,          # (B, 1) int32 (or embeds (B, 1, D))
    caches,
    cache_len: jax.Array,       # () int32 — current length (write position)
    compute_dtype=jnp.bfloat16,
    embeds: Optional[jax.Array] = None,
):
    """One decode step.  Returns (logits (B, 1, V), new_caches)."""
    if embeds is not None:
        B = embeds.shape[0]
    else:
        B = tokens.shape[0]
    positions = jnp.broadcast_to(cache_len[None, None], (B, 1)).astype(jnp.int32)

    if embeds is not None:
        x = embeds.astype(compute_dtype)
    else:
        x = params["embed"].astype(compute_dtype)[tokens]
    x = constrain(x, "batch", "seq", "d_model")

    plan = make_plan(cfg)
    new_caches = []
    for gi, g in enumerate(plan):
        gp = params["groups"][gi]
        gc = caches[gi]
        if g.kind in ("attn_dense", "attn_moe"):
            # Perf D4: cache enters the scan as READ-ONLY xs; each layer
            # emits only its new-token K/V (or latent) as tiny ys; a single
            # stacked DUS appends all layers' tokens afterwards.  No
            # per-layer cache copies (scan-ys) and no carry copies.
            use_moe = g.kind == "attn_moe"

            def body(x, p, c, _use_moe=use_moe):
                x, news = _attn_block_decode(
                    p, cfg, x, positions, c, cache_len, _use_moe
                )
                return x, news, jnp.zeros((), jnp.float32)

            x, _, news = _scan_group(body, x, gp["stacked"], gc, remat=False)
            nc = _append_tokens(gc, news, cache_len)
        elif g.kind == "hybrid":
            shared_p = gp["shared"]

            def body(x, p, c, _sp=shared_p):
                def inner(x, ip, ic):
                    h = rms_norm(ip["norm"], x, cfg.norm_eps)
                    y, nci = mamba2_decode(ip["mamba"], cfg, h, ic)
                    return x + y, nci, jnp.zeros((), jnp.float32)

                x, _, n_mc = _scan_group(inner, x, p, c["mamba"], remat=False)
                x, news = _attn_block_decode(
                    _sp, cfg, x, positions, c["attn"], cache_len, use_moe=False
                )
                return x, {"mamba": n_mc, "news": news}, jnp.zeros((), jnp.float32)

            x, _, outs = _scan_group(body, x, gp["stacked"], gc, remat=False)
            nc = {
                "mamba": outs["mamba"],
                "attn": _append_tokens(gc["attn"], outs["news"], cache_len),
            }
        elif g.kind == "xlstm":
            def body(x, p, c):
                def inner(x, ip, ic):
                    y, nc = mlstm_block(ip, cfg, x, ic)
                    return y, nc, jnp.zeros((), jnp.float32)

                x, _, n_ml = _scan_group(inner, x, p["mlstm"], c["mlstm"], remat=False)
                x, n_sl = slstm_block(p["slstm"], cfg, x, c["slstm"])
                return x, {"mlstm": n_ml, "slstm": n_sl}, jnp.zeros((), jnp.float32)

            x, _, nc = _scan_group(body, x, gp["stacked"], gc, remat=False)
        new_caches.append(nc)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["head"]
    ).astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, new_caches


def mtp_logits(
    params,
    cfg: ModelConfig,
    hidden: jax.Array,       # (B, S, D) post-final-norm hidden from lm_forward
    next_tokens: jax.Array,  # (B, S) the t+1 token ids (teacher-forced)
    compute_dtype=jnp.bfloat16,
):
    """DeepSeek-V3 multi-token-prediction head: predict token t+2.

    ``h' = Block(W_proj [h_t ; Emb(t_{t+1})])``, logits through the shared
    output head.  One extra (dense) transformer block, used in training only.
    """
    assert cfg.mtp and "mtp" in params
    B, S, D = hidden.shape
    emb = params["embed"].astype(compute_dtype)[next_tokens]
    h = jnp.concatenate([hidden.astype(compute_dtype), emb], axis=-1)
    h = jnp.einsum("bse,ed->bsd", h, params["mtp"]["proj"].astype(compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h, _, _ = _attn_block_apply(
        params["mtp"]["block"], cfg, h, positions, None, None, use_moe=False
    )
    h = rms_norm(params["mtp"]["norm"], h, cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["head"]
    ).astype(compute_dtype)
    return jnp.einsum("bsd,dv->bsv", h, head)
