"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar).

Follows the xLSTM paper (arXiv:2405.04517):

  * **mLSTM** — matrix memory ``C ∈ R^{hd×hd}`` per head with covariance
    update ``C_t = f_t C_{t-1} + i_t v_t k_t^T``, exponential input gating and
    a max-stabilizer ``m``.  Training/prefill use the *chunkwise* form
    (quadratic within a chunk, recurrent across chunks — same structure as
    Mamba2's SSD, so it shares the sub-quadratic long-context story); decode is
    the O(1) recurrence.
  * **sLSTM** — scalar memory per head with exponential gating and
    block-diagonal recurrent weights; inherently sequential (scanned over
    time), which is the architecture's stated trade-off.

Block wiring (xLSTM §4): mLSTM uses pre-up-projection (proj factor 2) with a
causal conv feeding q/k and an output gate from the parallel branch; sLSTM
uses post-up-projection (GeGLU MLP, factor 4/3).  ``d_ff = 0`` in the config
because all FFN capacity lives inside the blocks.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .common import Initializer, dense_init, rms_norm

__all__ = [
    "init_mlstm_block", "mlstm_specs", "mlstm_block",
    "MLSTMCache", "init_mlstm_cache",
    "init_slstm_block", "slstm_specs", "slstm_block",
    "SLSTMCache", "init_slstm_cache",
]


# --------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------- #
class MLSTMCache(NamedTuple):
    C: jax.Array     # (B, H, hd, hd) matrix memory
    n: jax.Array     # (B, H, hd) normalizer state
    m: jax.Array     # (B, H) max-stabilizer (log domain)
    conv: jax.Array  # (B, W-1, di) rolling conv window


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    xc = cfg.xlstm
    di = int(xc.mlstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    hd = di // nh
    return di, nh, hd


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    xc = cfg.xlstm
    di, nh, hd = _mlstm_dims(cfg)
    return MLSTMCache(
        C=jnp.zeros((batch, nh, hd, hd), jnp.float32),
        n=jnp.zeros((batch, nh, hd), jnp.float32),
        m=jnp.full((batch, nh), -1e30, jnp.float32),
        conv=jnp.zeros((batch, xc.conv_width - 1, di), dtype),
    )


def mlstm_specs(cfg: ModelConfig):
    """Logical-axis specs for :func:`init_mlstm_block` (no allocation)."""
    return {
        "norm": ("d_model",),
        "w_up": ("fsdp", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "w_q": ("fsdp", "ff"),
        "w_k": ("fsdp", "ff"),
        "w_v": ("fsdp", "ff"),
        "w_i": ("fsdp", "heads"),
        "w_f": ("fsdp", "heads"),
        "b_i": ("heads",),
        "b_f": ("heads",),
        "out_norm": ("ff",),
        "w_down": ("ff", "fsdp"),
    }


def init_mlstm_block(init: Initializer, cfg: ModelConfig):
    xc = cfg.xlstm
    d = cfg.d_model
    di, nh, hd = _mlstm_dims(cfg)
    params = {
        "norm": jnp.ones((d,), jnp.float32),
        "w_up": dense_init(init.next(), (d, 2 * di)),
        "conv_w": 0.1 * jax.random.normal(init.next(), (xc.conv_width, di), jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_q": dense_init(init.next(), (di, di)),
        "w_k": dense_init(init.next(), (di, di)),
        "w_v": dense_init(init.next(), (di, di)),
        "w_i": dense_init(init.next(), (di, nh)),
        "w_f": dense_init(init.next(), (di, nh)),
        "b_i": jnp.zeros((nh,), jnp.float32),
        # forget bias init: strongly open (remember) at start, as in the paper
        "b_f": jnp.linspace(3.0, 6.0, nh).astype(jnp.float32),
        "out_norm": jnp.ones((di,), jnp.float32),
        "w_down": dense_init(init.next(), (di, d)),
    }
    return params, mlstm_specs(cfg)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq.  x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _mlstm_chunked(q, k, v, log_i, log_f, state: Tuple, chunk: int):
    """Chunkwise stabilized mLSTM.

    q/k/v: (B, S, H, hd) f32; log_i/log_f: (B, S, H) f32.
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    Returns (h (B,S,H,hd), final_state).
    """
    B, S, H, hd = q.shape
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 3, 2, 4)  # (nc,B,H,q,hd)
    kc = k.reshape(B, nc, chunk, H, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nc, chunk, H, hd).transpose(1, 0, 3, 2, 4)
    lic = log_i.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)   # (nc,B,H,q)
    lfc = log_f.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)

    def body(carry, xs):
        C_prev, n_prev, m_prev = carry
        qk, kk, vk, li, lf = xs
        # inclusive within-chunk cumulative log-forget
        lf_cum = jnp.cumsum(lf, axis=-1)                      # (B,H,q)
        F = lf_cum[..., -1]                                   # (B,H)

        # intra-chunk decay matrix D[t,s] = lf_cum_t - lf_cum_s + li_s (s ≤ t)
        D = lf_cum[..., :, None] - lf_cum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri, D, -jnp.inf)                       # (B,H,q,q)

        # per-position stabilizer: max over intra contributions and carry-in
        b_in = lf_cum + m_prev[..., None]                     # (B,H,q)
        m_t = jnp.maximum(jnp.max(D, axis=-1), b_in)          # (B,H,q)
        m_t = jnp.maximum(m_t, -1e30)

        # intra attention-like weights
        Sw = jnp.exp(D - m_t[..., None])                      # (B,H,q,q)
        qk_scores = jnp.einsum("bhqd,bhkd->bhqk", qk, kk)     # (B,H,q,q)
        h_intra = jnp.einsum("bhqk,bhqk,bhkd->bhqd", Sw, qk_scores, vk)
        n_intra = jnp.einsum("bhqk,bhqk->bhq", Sw, qk_scores)

        # inter-chunk (carry) contribution
        w_in = jnp.exp(b_in - m_t)                            # (B,H,q)
        h_inter = jnp.einsum("bhqd,bhde->bhqe", qk, C_prev) * w_in[..., None]
        n_inter = jnp.einsum("bhqd,bhd->bhq", qk, n_prev) * w_in

        h_num = h_intra + h_inter
        n_tot = n_intra + n_inter
        denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_t))    # (B,H,q)
        h = h_num / denom[..., None]

        # chunk-end state update
        g = F[..., None] - lf_cum + li                        # (B,H,q) decay to end
        m_next = jnp.maximum(F + m_prev, jnp.max(g, axis=-1))
        m_next = jnp.maximum(m_next, -1e30)
        w_st = jnp.exp(g - m_next[..., None])                 # (B,H,q)
        C_new = (
            jnp.exp(F + m_prev - m_next)[..., None, None] * C_prev
            + jnp.einsum("bhq,bhqd,bhqe->bhde", w_st, kk, vk)
        )
        n_new = (
            jnp.exp(F + m_prev - m_next)[..., None] * n_prev
            + jnp.einsum("bhq,bhqd->bhd", w_st, kk)
        )
        return (C_new, n_new, m_next), h

    final, hs = jax.lax.scan(body, state, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return h, final


def mlstm_block(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    cache: Optional[MLSTMCache] = None,
) -> Tuple[jax.Array, Optional[MLSTMCache]]:
    """Residual mLSTM block.  x: (B, S, D)."""
    xc = cfg.xlstm
    di, nh, hd = _mlstm_dims(cfg)
    dt = x.dtype
    B, S, _ = x.shape

    h_in = rms_norm(params["norm"], x, cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h_in, params["w_up"].astype(dt))
    x_m, z = jnp.split(up, 2, axis=-1)                        # (B,S,di) each

    if cache is None:
        x_conv = jax.nn.silu(_causal_conv(x_m, params["conv_w"], params["conv_b"]))
        conv_tail = None
    else:
        win = jnp.concatenate([cache.conv.astype(dt), x_m], axis=1)
        x_conv = jax.nn.silu(
            _causal_conv(win, params["conv_w"], params["conv_b"])[:, -S:, :]
        )
        conv_tail = win[:, -(xc.conv_width - 1):, :]

    q = jnp.einsum("bse,ef->bsf", x_conv, params["w_q"].astype(dt))
    k = jnp.einsum("bse,ef->bsf", x_conv, params["w_k"].astype(dt)) * (hd ** -0.5)
    v = jnp.einsum("bse,ef->bsf", x_m, params["w_v"].astype(dt))
    q = constrain(q.reshape(B, S, nh, hd), "batch", "seq", "heads", None)
    k = constrain(k.reshape(B, S, nh, hd), "batch", "seq", "heads", None)
    v = constrain(v.reshape(B, S, nh, hd), "batch", "seq", "heads", None)

    log_i = (
        jnp.einsum("bse,eh->bsh", x_conv, params["w_i"].astype(dt)).astype(jnp.float32)
        + params["b_i"]
    )
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", x_conv, params["w_f"].astype(dt)).astype(jnp.float32)
        + params["b_f"]
    )

    if cache is None:
        state = (
            jnp.zeros((B, nh, hd, hd), jnp.float32),
            jnp.zeros((B, nh, hd), jnp.float32),
            jnp.full((B, nh), -1e30, jnp.float32),
        )
    else:
        state = (cache.C, cache.n, cache.m)

    chunk = min(xc.conv_width * 64, S)  # default 256, clipped to S
    while S % chunk:
        chunk //= 2
    h, (C_f, n_f, m_f) = _mlstm_chunked(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_i, log_f, state, chunk,
    )
    h = h.reshape(B, S, di).astype(dt)

    # per-head group norm ≈ rms over head dim, then output gate
    hf = h.astype(jnp.float32).reshape(B, S, nh, hd)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    hf = (hf * jax.lax.rsqrt(var + cfg.norm_eps)).reshape(B, S, di)
    h = (hf * params["out_norm"]).astype(dt)
    h = h * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", h, params["w_down"].astype(dt))

    new_cache = None
    if cache is not None:
        new_cache = MLSTMCache(C=C_f, n=n_f, m=m_f, conv=conv_tail.astype(cache.conv.dtype))
    return x + y, new_cache


# --------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------- #
class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, hd) cell
    n: jax.Array  # (B, H, hd) normalizer
    h: jax.Array  # (B, H, hd) hidden (recurrent input)
    m: jax.Array  # (B, H, hd) stabilizer
    conv: jax.Array  # (B, W-1, D)


def _slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return nh, hd


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    xc = cfg.xlstm
    nh, hd = _slstm_dims(cfg)
    return SLSTMCache(
        c=jnp.zeros((batch, nh, hd), jnp.float32),
        n=jnp.ones((batch, nh, hd), jnp.float32),
        h=jnp.zeros((batch, nh, hd), jnp.float32),
        m=jnp.zeros((batch, nh, hd), jnp.float32),
        conv=jnp.zeros((batch, xc.conv_width - 1, cfg.d_model), dtype),
    )


def slstm_specs(cfg: ModelConfig):
    """Logical-axis specs for :func:`init_slstm_block` (no allocation)."""
    return {
        "norm": ("d_model",), "conv_w": (None, "d_model"), "conv_b": ("d_model",),
        "w_z": ("fsdp", "d_model"), "w_i": ("fsdp", "d_model"),
        "w_f": ("fsdp", "d_model"), "w_o": ("fsdp", "d_model"),
        "r_z": ("heads", None, None), "r_i": ("heads", None, None),
        "r_f": ("heads", None, None), "r_o": ("heads", None, None),
        "b_z": ("d_model",), "b_i": ("d_model",), "b_f": ("d_model",),
        "b_o": ("d_model",), "gn": ("d_model",),
        "w_up_g": ("fsdp", "ff"), "w_up_v": ("fsdp", "ff"), "w_down": ("ff", "fsdp"),
    }


def init_slstm_block(init: Initializer, cfg: ModelConfig):
    xc = cfg.xlstm
    d = cfg.d_model
    nh, hd = _slstm_dims(cfg)
    df = int(xc.slstm_proj_factor * d)
    params = {
        "norm": jnp.ones((d,), jnp.float32),
        "conv_w": 0.1 * jax.random.normal(init.next(), (xc.conv_width, d), jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        # input weights for the four gates (z, i, f, o)
        "w_z": dense_init(init.next(), (d, d)),
        "w_i": dense_init(init.next(), (d, d)),
        "w_f": dense_init(init.next(), (d, d)),
        "w_o": dense_init(init.next(), (d, d)),
        # block-diagonal recurrent weights per head
        "r_z": 0.1 * jax.random.normal(init.next(), (nh, hd, hd), jnp.float32),
        "r_i": 0.1 * jax.random.normal(init.next(), (nh, hd, hd), jnp.float32),
        "r_f": 0.1 * jax.random.normal(init.next(), (nh, hd, hd), jnp.float32),
        "r_o": 0.1 * jax.random.normal(init.next(), (nh, hd, hd), jnp.float32),
        "b_z": jnp.zeros((d,), jnp.float32),
        "b_i": jnp.zeros((d,), jnp.float32),
        "b_f": jnp.full((d,), 3.0, jnp.float32),
        "b_o": jnp.zeros((d,), jnp.float32),
        "gn": jnp.ones((d,), jnp.float32),
        # post-up GeGLU MLP (proj factor 4/3)
        "w_up_g": dense_init(init.next(), (d, df)),
        "w_up_v": dense_init(init.next(), (d, df)),
        "w_down": dense_init(init.next(), (df, d)),
    }
    return params, slstm_specs(cfg)


def _slstm_step(params, nh, hd, state, gates):
    """One recurrent step.  gates: precomputed input contributions (B, 4, D)."""
    c, n, h, m = state
    gz, gi, gf, go = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    B = gz.shape[0]

    def rec(w, hh):  # block-diag recurrent matmul: (B,H,hd) × (H,hd,hd)
        return jnp.einsum("bhk,hkl->bhl", hh, w)

    z_t = jnp.tanh(gz.reshape(B, nh, hd) + rec(params["r_z"], h))
    i_pre = gi.reshape(B, nh, hd) + rec(params["r_i"], h)
    f_pre = gf.reshape(B, nh, hd) + rec(params["r_f"], h)
    o_t = jax.nn.sigmoid(go.reshape(B, nh, hd) + rec(params["r_o"], h))

    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z_t
    n_new = f_s * n + i_s
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    cache: Optional[SLSTMCache] = None,
) -> Tuple[jax.Array, Optional[SLSTMCache]]:
    """Residual sLSTM block (sequential scan over time).  x: (B, S, D)."""
    xc = cfg.xlstm
    nh, hd = _slstm_dims(cfg)
    dt = x.dtype
    B, S, D = x.shape

    h_in = rms_norm(params["norm"], x, cfg.norm_eps)
    if cache is None:
        xc_in = jax.nn.silu(_causal_conv(h_in, params["conv_w"], params["conv_b"]))
        conv_tail = None
    else:
        win = jnp.concatenate([cache.conv.astype(dt), h_in], axis=1)
        xc_in = jax.nn.silu(
            _causal_conv(win, params["conv_w"], params["conv_b"])[:, -S:, :]
        )
        conv_tail = win[:, -(xc.conv_width - 1):, :]

    # input contributions to the four gates, precomputed for the whole seq
    gz = jnp.einsum("bsd,de->bse", h_in, params["w_z"].astype(dt)) + params["b_z"].astype(dt)
    gi = jnp.einsum("bsd,de->bse", xc_in, params["w_i"].astype(dt)) + params["b_i"].astype(dt)
    gf = jnp.einsum("bsd,de->bse", xc_in, params["w_f"].astype(dt)) + params["b_f"].astype(dt)
    go = jnp.einsum("bsd,de->bse", h_in, params["w_o"].astype(dt)) + params["b_o"].astype(dt)
    gates = jnp.stack([gz, gi, gf, go], axis=2).astype(jnp.float32)  # (B,S,4,D)

    if cache is None:
        state = (
            jnp.zeros((B, nh, hd), jnp.float32),
            jnp.ones((B, nh, hd), jnp.float32),
            jnp.zeros((B, nh, hd), jnp.float32),
            jnp.zeros((B, nh, hd), jnp.float32),
        )
    else:
        state = (cache.c, cache.n, cache.h, cache.m)

    def body(st, g):
        return _slstm_step(params, nh, hd, st, g)

    final, hs = jax.lax.scan(body, state, gates.transpose(1, 0, 2, 3))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(dt)

    # group norm over heads
    hf = h.astype(jnp.float32).reshape(B, S, nh, hd)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    hf = (hf * jax.lax.rsqrt(var + cfg.norm_eps)).reshape(B, S, D)
    h = (hf * params["gn"]).astype(dt)

    # post-up GeGLU MLP
    g = jnp.einsum("bsd,df->bsf", h, params["w_up_g"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", h, params["w_up_v"].astype(dt))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, params["w_down"].astype(dt))

    new_cache = None
    if cache is not None:
        c, n, hh, m = final
        new_cache = SLSTMCache(c=c, n=n, h=hh, m=m, conv=conv_tail.astype(cache.conv.dtype))
    return x + y, new_cache
