"""Data substrate: synthetic streams, resumable token pipeline, SSSJ dedup."""

from .pipeline import DedupFilter, TokenPipeline  # noqa: F401
from .synth import (  # noqa: F401
    DATASET_SPECS, StreamSpec, dense_embedding_stream, planted_duplicates,
    synthetic_stream,
)
