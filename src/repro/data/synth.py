"""Synthetic stream generators mirroring the paper's datasets (Table 1).

The paper evaluates on four corpora with very different densities and
timestamp processes:

  ========  =========  =========  ========  ===============
  dataset   n          dims       |x| avg   timestamps
  ========  =========  =========  ========  ===============
  WebSpam   350 000    680 715    3728      poisson
  RCV1      804 414    43 001     75.7      sequential
  Blogs     2 532 437  356 043    140.4     publishing date
  Tweets    18 266 589 1 048 576  9.46      publishing date
  ========  =========  =========  ========  ===============

The container is offline, so the benchmark harness uses *scaled-down
synthetic analogues*: term ids drawn from a Zipfian popularity law (as in
natural text), per-item nnz from a log-normal around the target density,
and the matching timestamp process (poisson / sequential / bursty —
"publishing date" streams are bursty, which is what stresses the window).
Scale factors are recorded in benchmark output so numbers are comparable
across runs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List

import numpy as np

from ..core.types import SparseVector, StreamItem, make_sparse, unit_normalize

__all__ = [
    "StreamSpec",
    "DATASET_SPECS",
    "synthetic_stream",
    "bursty_tenant_traffic",
    "dense_embedding_stream",
    "planted_duplicates",
    "topic_drift_stream",
]


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Characteristics of a synthetic stream (a scaled Table-1 analogue)."""

    name: str
    n: int
    dims: int
    avg_nnz: float
    timestamps: str  # "poisson" | "sequential" | "bursty"
    zipf_a: float = 1.3
    rate: float = 1.0  # mean arrivals per time unit


# Scaled-down analogues of Table 1 (n reduced ~100–1000×, dims ~20×; density
# and timestamp character preserved).
DATASET_SPECS = {
    "webspam": StreamSpec("webspam", 3_500, 8_192, 360.0, "poisson"),
    "rcv1": StreamSpec("rcv1", 8_000, 4_096, 75.0, "sequential"),
    "blogs": StreamSpec("blogs", 12_000, 8_192, 40.0, "bursty"),
    "tweets": StreamSpec("tweets", 20_000, 16_384, 9.5, "bursty"),
}


def _timestamps(spec: StreamSpec, rng: np.random.Generator) -> np.ndarray:
    if spec.timestamps == "sequential":
        return np.arange(spec.n, dtype=np.float64) / spec.rate
    if spec.timestamps == "poisson":
        return np.cumsum(rng.exponential(1.0 / spec.rate, size=spec.n))
    if spec.timestamps == "bursty":
        # Burst process: exponential gaps whose rate itself jumps between a
        # slow and a fast regime (heavy temporal clustering, like publishing
        # dates around events).
        gaps = np.empty(spec.n)
        i = 0
        while i < spec.n:
            burst = int(rng.integers(5, 50))
            fast = bool(rng.random() < 0.5)
            rate = spec.rate * (10.0 if fast else 0.2)
            k = min(burst, spec.n - i)
            gaps[i : i + k] = rng.exponential(1.0 / rate, size=k)
            i += k
        return np.cumsum(gaps)
    raise ValueError(f"unknown timestamp process {spec.timestamps!r}")


def synthetic_stream(spec: StreamSpec, seed: int = 0) -> List[StreamItem]:
    """Generate a sparse, unit-normalized, Zipf-termed stream."""
    rng = np.random.default_rng(seed)
    ts = _timestamps(spec, rng)
    # Zipfian term popularity over the dimension space
    ranks = np.arange(1, spec.dims + 1, dtype=np.float64)
    probs = ranks ** (-spec.zipf_a)
    probs /= probs.sum()
    sigma = 0.6
    mu = math.log(max(spec.avg_nnz, 1.5)) - sigma**2 / 2
    items: List[StreamItem] = []
    for i in range(spec.n):
        nnz = int(np.clip(rng.lognormal(mu, sigma), 1, spec.dims // 2))
        idx = np.unique(rng.choice(spec.dims, size=nnz, p=probs))
        val = rng.random(idx.shape[0]) + 0.05
        items.append(
            StreamItem(i, float(ts[i]), unit_normalize(make_sparse(idx, val)))
        )
    return items


def dense_embedding_stream(
    n: int,
    d: int,
    seed: int = 0,
    rate: float = 1.0,
    dup_frac: float = 0.15,
    dup_noise: float = 0.05,
    signed: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Dense unit-vector stream with planted near-duplicates.

    Returns ``(vectors (n, d), timestamps (n,))``.  A ``dup_frac`` fraction
    of items are noisy copies of a recent earlier item — the ground truth
    for near-duplicate detection (the paper's application #2).
    """
    rng = np.random.default_rng(seed)
    ts = np.cumsum(rng.exponential(1.0 / rate, size=n))
    base = rng.standard_normal((n, d))
    if not signed:
        base = np.abs(base)
    for i in range(1, n):
        if rng.random() < dup_frac:
            src = int(rng.integers(max(0, i - 64), i))
            base[i] = base[src] + dup_noise * rng.standard_normal(d)
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    return base.astype(np.float32), ts.astype(np.float64)


def topic_drift_stream(
    n: int,
    d: int,
    n_topics: int = 8,
    seg: int = 512,
    seed: int = 0,
    rate: float = 1.0,
    in_spread: float = 0.25,
    leak: float = 0.02,
) -> tuple[np.ndarray, np.ndarray]:
    """Topically clustered unit-vector stream for value-bound pruning.

    The stream dwells on one topic for ``seg`` consecutive items, then
    jumps to another.  Each topic owns a disjoint block of ``d //
    n_topics`` coordinates: in-block weights are ``|N(1, in_spread²)|``
    and out-of-block weights ``N(0, leak²)``, so after normalization
    cross-topic cosines sit far below any useful threshold while
    same-topic cosines sit far above it.  This is the structure that
    lets per-strip vmax/chunk-norm summaries prove whole window strips
    irrelevant to a query batch — an isotropic stream defeats value
    bounds by construction (every strip's per-dimension max is uniform).

    Returns ``(vectors (n, d) f32, timestamps (n,) f64)``.
    """
    if d % n_topics:
        raise ValueError(f"d={d} must be divisible by n_topics={n_topics}")
    rng = np.random.default_rng(seed)
    bw = d // n_topics
    vecs = rng.normal(0.0, leak, size=(n, d))
    topic = -1
    for s0 in range(0, n, seg):
        step = int(rng.integers(1, n_topics))  # never re-draw the same topic
        topic = (topic + step) % n_topics if topic >= 0 else int(rng.integers(n_topics))
        k = min(seg, n - s0)
        vecs[s0 : s0 + k, topic * bw : (topic + 1) * bw] = np.abs(
            rng.normal(1.0, in_spread, size=(k, bw))
        )
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    ts = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return vecs.astype(np.float32), ts.astype(np.float64)


def bursty_tenant_traffic(
    n_slow: int,
    rounds: int,
    burst: int,
    d: int,
    seed: int = 7,
    repost_gap: float = 1.5,
    dup_noise: float = 0.02,
):
    """Multi-tenant flood traffic: the eviction-policy stress stream
    shared by the conformance suite, the bursty benchmark, and the
    example (DESIGN.md §11).

    Tenant 0 floods ``burst`` random unit vectors per round; slow tenants
    ``1..n_slow`` each repost a noisy copy of their own base vector once
    per round, ``repost_gap`` time units apart — so consecutive reposts
    pair *iff* the previous one still lives in the window, which is
    exactly what a bursty co-tenant threatens under oldest-first
    eviction.

    Returns ``(submits, per_tenant)``: ``submits`` is a time-ordered list
    of ``(tenant, vecs (b, d) f32, ts (b,))`` submit calls, and
    ``per_tenant[k]`` is tenant *k*'s full ``(vecs, ts)`` stream in local
    index order (the brute-force-truth input).
    """
    rng = np.random.default_rng(seed)
    bases = rng.standard_normal((n_slow + 1, d))
    submits = []
    streams: List[list] = [[] for _ in range(n_slow + 1)]
    for r in range(rounds):
        t0 = repost_gap * r
        for k in range(1, n_slow + 1):
            v = bases[k] + dup_noise * rng.standard_normal(d)
            v = (v / np.linalg.norm(v)).astype(np.float32)
            tk = np.array([t0 + 0.01 * k])
            streams[k].append((v[None], tk))
            submits.append((k, v[None], tk))
        vb = rng.standard_normal((burst, d))
        vb = (vb / np.linalg.norm(vb, axis=1, keepdims=True)).astype(np.float32)
        tb = t0 + 0.1 + 0.003 * np.arange(burst)
        streams[0].append((vb, tb))
        submits.append((0, vb, tb))
    per_tenant = [
        (np.concatenate([v for v, _ in s]), np.concatenate([t for _, t in s]))
        for s in streams
    ]
    return submits, per_tenant


def planted_duplicates(
    vectors: np.ndarray, ts: np.ndarray, theta: float, lam: float
) -> set[tuple[int, int]]:
    """Ground-truth decayed-similar pair set for a dense stream (testing)."""
    sims = vectors @ vectors.T
    dts = np.abs(ts[:, None] - ts[None, :])
    dec = sims * np.exp(-lam * dts)
    n = vectors.shape[0]
    out = set()
    for i in range(n):
        for j in range(i):
            if dec[i, j] >= theta:
                out.add((j, i))
    return out
