"""Sharded, resumable token pipeline with a streaming-dedup stage.

``TokenPipeline`` produces deterministic synthetic LM batches:

  * **sharded** — each host generates only its shard (``host_id/num_hosts``)
    from a per-(step, shard) PRNG key: no host ever materializes the global
    batch;
  * **resumable** — state is just ``(seed, step)``; checkpointing it gives
    exact resume (no sample loss or duplication), verified in tests;
  * **dedup-filtered** — the paper's application #2 as a pipeline stage:
    documents are embedded (hashing projection — cheap, model-free),
    unit-normalized, timestamped, and pushed through the streaming
    similarity self-join; near-duplicates within the time horizon are
    dropped *before batching* and replaced by fresh samples.

The dedup stage runs the device-resident engine (repro.engine) so the same
code path scales from this CPU container to the sharded fan-out.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..engine.engine import EngineConfig, StreamEngine

__all__ = ["TokenPipeline", "DedupFilter", "hashing_embed"]


def hashing_embed(tokens: np.ndarray, dim: int, seed: int = 17) -> np.ndarray:
    """Model-free document embedding: hashed bag-of-tokens projection.

    Each vocabulary id deterministically hashes to a ±1 position in ``dim``
    buckets (feature hashing); document vectors are unit-normalized.  Near-
    duplicate documents (high token overlap) get high cosine similarity —
    exactly the regime the paper's join targets.
    """
    tokens = np.asarray(tokens)
    rng_a = 1103515245
    h = (tokens.astype(np.int64) * rng_a + seed) % (2 ** 31)
    bucket = (h % dim).astype(np.int64)
    sign = np.where((h // dim) % 2 == 0, 1.0, -1.0).astype(np.float32)
    n = tokens.shape[0]
    out = np.zeros((n, dim), np.float32)
    rows = np.repeat(np.arange(n), tokens.shape[1])
    np.add.at(out, (rows, bucket.ravel()), sign.ravel())
    norm = np.linalg.norm(out, axis=1, keepdims=True)
    return out / np.maximum(norm, 1e-9)


class DedupFilter:
    """Streaming near-duplicate filter over document embeddings (paper §1,
    application #2), backed by the device-resident SSSJ engine.

    A keep-mask only needs "does row i have a ≥ θ match" — not the matches
    themselves — so this consumer rides the engine's per-row match mask
    (DESIGN.md §3): a ``(micro_batch,)`` boolean derived from level-1 emit
    counts, exact regardless of candidate-buffer capacity.  This removes
    the old lossless bound ``max_pairs = block·(capacity+block)`` (under
    which the compacted buffers could exceed the dense matrices they
    replaced): pair emission is vestigial here, its buffers are held at
    the minimum, and any pair-drop counters that fire are irrelevant to
    correctness — host traffic is O(block) per push.
    """

    def __init__(
        self,
        theta: float = 0.9,
        lam: float = 0.05,
        dim: int = 256,
        capacity: int = 2048,
        block: int = 64,
    ) -> None:
        self.cfg = EngineConfig(
            theta=theta, lam=lam, capacity=capacity, d=dim,
            micro_batch=block, max_pairs=8, tile_k=8,
            block_q=block, block_w=block, chunk_d=min(dim, 128),
        )
        self.engine = StreamEngine(self.cfg)
        self.dim = dim
        self.n_seen = 0
        self.n_dropped = 0

    def filter(self, tokens: np.ndarray, ts: np.ndarray) -> np.ndarray:
        """Returns a boolean keep-mask for the batch of documents."""
        emb = hashing_embed(tokens, self.dim)
        self.engine.push(emb, ts)
        # the mask marks the *newer* item of each similar pair (the join's
        # uid-order mask makes the query side strictly newer)
        _, _, _, matched = self.engine.drain_arrays(return_masks=True)
        keep = ~matched
        self.n_seen += tokens.shape[0]
        self.n_dropped += int((~keep).sum())
        return keep


@dataclasses.dataclass
class _PipelineState:
    seed: int
    step: int


class TokenPipeline:
    """Deterministic sharded LM batches with optional streaming dedup."""

    def __init__(
        self,
        vocab_size: int,
        batch: int,                # per-host batch
        seq_len: int,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
        dup_frac: float = 0.0,     # planted near-duplicate rate (for dedup)
        dedup: Optional[DedupFilter] = None,
    ) -> None:
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.dup_frac = dup_frac
        self.dedup = dedup
        self.state = _PipelineState(seed=seed, step=0)
        self._last: Optional[np.ndarray] = None

    # ------------------------------------------------------------------ #
    def checkpoint_state(self) -> Dict:
        return {"seed": self.state.seed, "step": self.state.step}

    def restore_state(self, d: Dict) -> None:
        self.state = _PipelineState(seed=int(d["seed"]), step=int(d["step"]))
        self._last = None

    # ------------------------------------------------------------------ #
    def _rng(self, step: int, salt: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            (self.state.seed * 1_000_003 + step) * 4096
            + self.host_id * 7 + salt
        )

    def _sample(self, step: int, salt: int = 0) -> np.ndarray:
        rng = self._rng(step, salt)
        toks = rng.integers(
            1, self.vocab_size, (self.batch, self.seq_len), dtype=np.int64
        )
        if self.dup_frac > 0.0 and self._last is not None:
            # plant near-duplicates of recent documents (5% token noise)
            for i in range(self.batch):
                if rng.random() < self.dup_frac:
                    src = self._last[int(rng.integers(0, self._last.shape[0]))]
                    noise = rng.random(self.seq_len) < 0.05
                    dup = np.where(
                        noise,
                        rng.integers(1, self.vocab_size, self.seq_len),
                        src,
                    )
                    toks[i] = dup
        return toks

    def next_batch(self) -> Dict[str, np.ndarray]:
        step = self.state.step
        toks = self._sample(step)
        if self.dedup is not None:
            ts = np.full((self.batch,), float(step), np.float64)
            keep = self.dedup.filter(toks, ts)
            salt = 1
            # replace dropped documents with fresh (non-planted) samples
            while not keep.all():
                fresh = self._sample(step, salt)
                toks[~keep] = fresh[~keep]
                keep[:] = True
                salt += 1
        self._last = toks
        self.state.step += 1
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {
            "tokens": toks.astype(np.int32),
            "labels": labels.astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
