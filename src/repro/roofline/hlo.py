"""HLO cost walker: FLOPs / HBM bytes / collective bytes with loop trips.

``compiled.cost_analysis()`` does not multiply ``while`` bodies by their
trip count, which makes it useless for scanned (layer-stacked, microbatched)
programs — it undercounts a 28-layer×16-microbatch train step by ~450×.
This walker parses the optimized HLO text and computes:

  * **flops** — 2·|out|·|contract| for every ``dot``, recursively through
    called computations, ``while`` bodies multiplied by their
    ``known_trip_count`` (emitted by XLA for counted loops);
  * **hbm_bytes** — Σ (operand + output bytes) of top-level instructions;
    fusion *bodies* are skipped (internal to one kernel) but the fusion's
    own operands/outputs are counted — a standard traffic approximation;
  * **collective bytes by kind** — operand bytes of each collective, also
    trip-multiplied (a ppermute inside a scanned layer counts L times).

This is the project's "profile" on a CPU-only container: structural, not
wall-clock, but loop-aware and shape-exact.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

# ops whose operands/outputs do not represent real HBM traffic
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "iota",
}

# ops that read only the region they produce (not their full operand):
# counting the full operand would charge a 28-layer scan 28× the stacked
# weight bytes for its per-layer dynamic-slice.
_OUTPUT_ONLY_BYTES = {
    "dynamic-slice", "slice", "gather", "broadcast", "reshape", "pad",
    "reverse", "transpose",
}

# in-place update ops: traffic ≈ 2 × update-region bytes (read-modify-write),
# NOT the full target buffer.
_UPDATE_OPS = {"dynamic-update-slice": 1, "scatter": 2}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+\"?(\d+)')
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_list_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] shape literal in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2).strip() else []
        out.append((m.group(1), dims))
    return out


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    out_shape: str           # text of the output shape
    operands: str            # text inside the operand parens
    attrs: str               # text after the operand parens
    line: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_ops: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops_by_meta: Dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_bytes_by_site: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_ops.items():
            self.collective_ops[k] = self.collective_ops.get(k, 0.0) + v * mult
        for k, v in other.dot_flops_by_meta.items():
            self.dot_flops_by_meta[k] = (
                self.dot_flops_by_meta.get(k, 0.0) + v * mult
            )
        for k, v in other.hbm_bytes_by_site.items():
            self.hbm_bytes_by_site[k] = (
                self.hbm_bytes_by_site.get(k, 0.0) + v * mult
            )


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{", line)
            if m:
                cur = m.group(1)
                body = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = body
                comps[cur] = body
        else:
            if stripped == "}":
                cur = None
            else:
                body.append(line)
    return comps


def _parse_instr(line: str) -> Optional[_Instr]:
    m = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # output shape: balanced parens for tuples, else up to first space
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        out_shape = rest[: i + 1]
        rest2 = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_shape = rest[:sp]
        rest2 = rest[sp + 1:]
    om = re.match(r"([\w\-]+)\(", rest2)
    if not om:
        return None
    opcode = om.group(1)
    # operands: balanced parens from the opcode's open paren
    start = om.end() - 1
    depth = 0
    for i in range(start, len(rest2)):
        if rest2[i] == "(":
            depth += 1
        elif rest2[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operands = rest2[start + 1 : i]
    attrs = rest2[i + 1 :]
    return _Instr(name, opcode, out_shape, operands, attrs, line)


def _dot_flops(instr: _Instr, symtab: Dict[str, str]) -> float:
    out_dims = _shape_dims(instr.out_shape)
    out_elems = 1
    for _, dims in out_dims:
        for d in dims:
            out_elems *= d
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    refs = _NAME_RE.findall(instr.operands)
    # lhs shape: prefer inline shape in the operand text, else symbol table
    lhs_shapes = _shape_dims(instr.operands)
    if lhs_shapes:
        lhs_dims = lhs_shapes[0][1]
    elif refs and refs[0] in symtab:
        sh = _shape_dims(symtab[refs[0]])
        lhs_dims = sh[0][1] if sh else []
    else:
        lhs_dims = []
    k = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx.strip() and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    parsed: Dict[str, List[_Instr]] = {}
    symtabs: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        instrs = []
        sym: Dict[str, str] = {}
        for line in lines:
            ins = _parse_instr(line)
            if ins is None:
                continue
            instrs.append(ins)
            sym[ins.name] = ins.out_shape
        parsed[cname] = instrs
        symtabs[cname] = sym

    # computations called as fusion bodies never touch HBM themselves
    fusion_bodies = set()
    for instrs in parsed.values():
        for ins in instrs:
            if ins.opcode == "fusion":
                fm = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if fm:
                    fusion_bodies.add(fm.group(1))

    memo: Dict[Tuple[str, bool], HloCost] = {}

    def cost_of(cname: str, count_bytes: bool) -> HloCost:
        key = (cname, count_bytes)
        if key in memo:
            return memo[key]
        total = HloCost()
        memo[key] = total  # break cycles defensively
        for ins in parsed.get(cname, []):
            op = ins.opcode
            if op == "while":
                tm = _TRIP_RE.search(ins.attrs)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%([\w.\-]+)", ins.attrs)
                cm = re.search(r"condition=%([\w.\-]+)", ins.attrs)
                if bm:
                    total.add(cost_of(bm.group(1), count_bytes), trips)
                if cm:
                    total.add(cost_of(cm.group(1), count_bytes), trips)
                continue
            if op == "fusion":
                fm = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if fm:
                    total.add(cost_of(fm.group(1), count_bytes=False))
                if count_bytes:
                    fb = _fusion_bytes(
                        ins, symtabs[cname], fm.group(1) if fm else None
                    )
                    total.hbm_bytes += fb
                    site = _site(ins)
                    total.hbm_bytes_by_site[site] = (
                        total.hbm_bytes_by_site.get(site, 0.0) + fb
                    )
                continue
            if op in ("call", "custom-call") and "to_apply=" in ins.attrs:
                am = re.search(r"to_apply=%([\w.\-]+)", ins.attrs)
                if am:
                    total.add(cost_of(am.group(1), count_bytes))
                continue
            if op == "conditional":
                for bm in re.finditer(
                    r"(?:branch_computations=\{([^}]*)\}|true_computation=%([\w.\-]+)|false_computation=%([\w.\-]+))",
                    ins.attrs,
                ):
                    names = bm.group(1)
                    if names:
                        for n in _NAME_RE.findall(names):
                            total.add(cost_of(n, count_bytes))
                    else:
                        for g in (bm.group(2), bm.group(3)):
                            if g:
                                total.add(cost_of(g, count_bytes))
                continue
            base_kind = op.replace("-start", "")
            if base_kind in _COLLECTIVES and not op.endswith("-done"):
                nbytes = _operand_bytes(ins, symtabs[cname])
                if nbytes == 0:
                    nbytes = _shape_list_bytes(ins.out_shape)
                total.collective_bytes[base_kind] = (
                    total.collective_bytes.get(base_kind, 0.0) + nbytes
                )
                total.collective_ops[base_kind] = (
                    total.collective_ops.get(base_kind, 0.0) + 1
                )
                if count_bytes:
                    total.hbm_bytes += nbytes
                continue
            if op == "dot":
                fl = _dot_flops(ins, symtabs[cname])
                total.flops += fl
                meta = re.search(r'op_name="([^"]*)"', ins.attrs)
                label = meta.group(1) if meta else "unlabeled"
                total.dot_flops_by_meta[label] = (
                    total.dot_flops_by_meta.get(label, 0.0) + fl
                )
            if count_bytes and op not in _NO_BYTES:
                if op in _OUTPUT_ONLY_BYTES:
                    b = _shape_list_bytes(ins.out_shape)
                elif op in _UPDATE_OPS:
                    per_op = _per_operand_bytes(ins, symtabs[cname])
                    idx = _UPDATE_OPS[op]
                    upd = per_op[idx] if idx < len(per_op) else (
                        per_op[-1] if per_op else 0
                    )
                    b = 2 * upd
                else:
                    b = _shape_list_bytes(ins.out_shape) + _operand_bytes(
                        ins, symtabs[cname]
                    )
                total.hbm_bytes += b
                site = _site(ins)
                total.hbm_bytes_by_site[site] = (
                    total.hbm_bytes_by_site.get(site, 0.0) + b
                )
        return total

    def _site(ins: _Instr) -> str:
        m = re.search(r'op_name="([^"]*)"', ins.attrs)
        tag = m.group(1) if m else "unlabeled"
        return f"{ins.opcode}::{tag}"

    def _split_top_commas(text: str) -> List[str]:
        out, depth, cur = [], 0, []
        for ch in text:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                out.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        if cur:
            out.append("".join(cur))
        return out

    def _per_operand_bytes(ins: _Instr, sym: Dict[str, str]) -> List[int]:
        out = []
        for chunk in _split_top_commas(ins.operands):
            b = _shape_list_bytes(chunk)
            if b == 0:
                for ref in _NAME_RE.findall(chunk):
                    if ref in sym:
                        b += _shape_list_bytes(sym[ref])
            out.append(b)
        return out

    def _operand_bytes(ins: _Instr, sym: Dict[str, str]) -> int:
        return sum(_per_operand_bytes(ins, sym))

    def _fusion_bytes(ins: _Instr, sym: Dict[str, str],
                      body_name: Optional[str]) -> int:
        """Traffic of one fusion: output + operands, adjusted for windowed
        access inside the body.

        * a ``dynamic-update-slice`` on a fusion parameter is in-place: the
          read side of that parameter and the write side of the output are
          both just the update window (XLA aliases the buffer);
        * a ``dynamic-slice`` / ``gather`` / ``slice`` of a parameter reads
          only the produced window.
        """
        per_op = _per_operand_bytes(ins, sym)
        out_b = _shape_list_bytes(ins.out_shape)
        if body_name is None or body_name not in parsed:
            return out_b + sum(per_op)
        body = parsed[body_name]
        bsym = symtabs[body_name]
        # parameter name → operand index
        p_idx: Dict[str, int] = {}
        for b in body:
            if b.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", b.line)
                if pm:
                    p_idx[b.name] = int(pm.group(1))
        adjusted = list(per_op)
        out_adj: Optional[int] = None
        for b in body:
            refs = _NAME_RE.findall(b.operands)
            if b.opcode == "dynamic-update-slice" and len(refs) >= 2:
                upd = _shape_list_bytes(bsym.get(refs[1], ""))
                tgt = refs[0]
                if tgt in p_idx and p_idx[tgt] < len(adjusted):
                    adjusted[p_idx[tgt]] = min(adjusted[p_idx[tgt]], upd)
                if b.line.lstrip().startswith("ROOT"):
                    out_adj = upd
            elif b.opcode in ("dynamic-slice", "slice", "gather") and refs:
                win = _shape_list_bytes(b.out_shape)
                src = refs[0]
                if src in p_idx and p_idx[src] < len(adjusted):
                    adjusted[p_idx[src]] = min(adjusted[p_idx[src]], win)
        return (out_adj if out_adj is not None else out_b) + sum(adjusted)

    entry = "__entry__" if "__entry__" in parsed else next(iter(parsed))
    return cost_of(entry, count_bytes=True)
