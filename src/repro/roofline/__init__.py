"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in *seconds for one step*:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = collective_bytes_per_device / ICI_link_bandwidth

``compiled.cost_analysis()`` reports the per-device (post-SPMD-partition)
program, so no further division by chip count is needed.  Collective bytes
are not in cost_analysis — they are parsed from the optimized HLO
(``compiled.as_text()``) by summing operand sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute /
ragged-all-to-all op.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(the ``pod`` axis crosses DCN at ~6.4 GB/s/host guess; cross-pod collectives
are counted separately when the HLO's replica groups span pods).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = [
    "HW", "CollectiveStats", "parse_collective_bytes", "roofline_terms",
    "model_flops", "active_param_count",
]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip (v5e)
    hbm_bw: float = 819e9           # B/s per chip
    ici_bw: float = 50e9            # B/s per ICI link
    dcn_bw: float = 6.4e9           # B/s per host crossing DCN ("pod" axis)


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

# one HLO instruction: "%name = <shape> opcode(<operands>)..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) +
    r")(?:-start|-done)?\((.*?)\)", re.M
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    by_kind: Dict[str, int]
    ops: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.by_kind.values())


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in (per-device) optimized HLO.

    ``-done`` ops are skipped (their ``-start`` twin already counted).
    """
    by_kind: Dict[str, int] = {}
    ops: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        out_shape, kind, operands = m.group(1), m.group(2), m.group(3)
        full = m.group(0)
        if f"{kind}-done" in full:
            continue
        nbytes = 0
        for sm in _SHAPE_RE.finditer(operands):
            nbytes += _shape_bytes(sm.group(1), sm.group(2))
        if nbytes == 0:
            # operand list may elide shapes (e.g. "%param.3"); fall back to
            # the output shape (same size for permute/all-reduce)
            for sm in _SHAPE_RE.finditer(out_shape):
                nbytes += _shape_bytes(sm.group(1), sm.group(2))
        by_kind[kind] = by_kind.get(kind, 0) + nbytes
        ops[kind] = ops.get(kind, 0) + 1
    return CollectiveStats(by_kind=by_kind, ops=ops)


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    collective_bytes_per_dev: float,
    hw: HW = V5E,
) -> Dict[str, float]:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = collective_bytes_per_dev / hw.ici_bw
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    total = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": total,
        "flops_per_dev": flops_per_dev,
        "bytes_per_dev": bytes_per_dev,
        "collective_bytes_per_dev": collective_bytes_per_dev,
    }


# ------------------------------------------------------------------ #
# analytic MODEL_FLOPS (the "useful compute" yardstick)
# ------------------------------------------------------------------ #
def active_param_count(cfg) -> int:
    """Active (per-token) parameter count, analytic, excluding embeddings.

    For MoE: dense layers + shared expert + top_k routed experts + router.
    """
    from ..models.lm import param_count

    total = param_count(cfg)
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.moe is None:
        return total - emb
    mc = cfg.moe
    n_moe_layers = cfg.n_layers - mc.n_dense_layers
    per_expert = 3 * cfg.d_model * mc.d_ff_expert
    routed_total = n_moe_layers * mc.n_experts * per_expert
    routed_active = n_moe_layers * mc.top_k * per_expert
    return total - emb - routed_total + routed_active


def model_flops(cfg, shape, n_active: Optional[int] = None) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (prefill/decode).

    The classic transformer yardstick; attention's S² term is excluded, so
    the reported MODEL_FLOPS/HLO_FLOPs ratio < 1 even for a perfect
    implementation at long context (stated alongside the table).
    """
    n = n_active if n_active is not None else active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
