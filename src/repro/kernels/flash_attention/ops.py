"""Public wrapper for the flash attention kernel (forward-only, prefill)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel_call
from .ref import attention_ref

__all__ = ["flash_attention"]


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "block_q", "block_k", "interpret", "use_ref"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    sm_scale: Optional[float] = None,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
    use_ref: bool = False,
) -> jax.Array:
    """Causal flash attention.  q: (B, H, Sq, Dh); k, v: (B, Hkv, Sk, Dh).

    Sequence lengths are padded to block multiples internally; padded kv
    positions are masked through the causal structure for self-attention
    (Sq == Sk).  For simplicity the wrapper requires Sq == Sk when causal.
    """
    B, H, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    if H % Hkv != 0:
        raise ValueError(f"H={H} not a multiple of Hkv={Hkv}")
    if causal and Sq != Sk:
        raise ValueError("causal path expects self-attention (Sq == Sk)")
    scale = sm_scale if sm_scale is not None else Dh ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_ref:
        return attention_ref(q, k, v, sm_scale=scale, causal=causal)

    bq = min(block_q, _round_up(Sq))
    bk = min(block_k, _round_up(Sk))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    if not causal and pk:
        # mask padded kv by pushing keys to -inf attention: implemented by
        # padding k with zeros and masking in-kernel is causal-only; for the
        # non-causal path fall back to the reference (only used in tests).
        return attention_ref(q, k, v, sm_scale=scale, causal=causal)
    out = flash_attention_kernel_call(
        qp, kp, vp,
        sm_scale=scale, causal=causal, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    return out[:, :, :Sq, :]


def _round_up(n: int, mult: int = 8) -> int:
    return ((n + mult - 1) // mult) * mult
