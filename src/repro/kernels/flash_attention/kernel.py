"""Pallas TPU kernel: causal flash attention (prefill path).

Canonical TPU formulation: grid ``(batch, q_heads, n_q_blocks, n_kv_blocks)``
with the kv-block dimension innermost (sequential on TPU), carrying the
online-softmax state — running max ``m``, normalizer ``l`` and the output
accumulator — in VMEM scratch across kv steps.  GQA is handled in the
BlockSpec index maps (query head ``h`` reads kv head ``h // group``), so no
materialized K/V repetition is needed.

Causality is enforced at two granularities:

  * whole kv blocks strictly above the diagonal are skipped via ``pl.when``
    (no MXU work — the analogue of the SSSJ kernel's dead-tile skip);
  * the diagonal block applies an elementwise mask.

The kernel is used for TPU serving prefill; training uses the XLA path
(this kernel is forward-only).  Validated in interpret mode against
``ref.py`` over shape/dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,            # inputs
    o_ref,                          # output
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *, sm_scale: float, block_q: int, block_k: int, n_kv_blocks: int, causal: bool,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    f32 = jnp.float32

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Skip kv blocks strictly in the causal future of this q block: program
    # ids are traced, so the skip is a dynamic pl.when (no MXU work done).
    should_run = jnp.asarray(True) if not causal else (
        ik * block_k <= iq * block_q + block_q - 1
    )

    @pl.when(should_run)
    def _step():
        q = q_ref[0, 0].astype(f32) * sm_scale          # (bq, dh)
        k = k_ref[0, 0].astype(f32)                     # (bk, dh)
        v = v_ref[0, 0].astype(f32)                     # (bk, dh)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=f32,
        )                                               # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0
            )
            cols = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1
            )
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[:, 0]                            # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)                 # (bq,)
        p = jnp.exp(s - m_cur[:, None])                 # (bq, bk)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=f32,
        )
        m_ref[:, 0] = m_cur

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,   # (B, H, Sq, Dh)
    k: jax.Array,   # (B, Hkv, Sk, Dh)
    v: jax.Array,   # (B, Hkv, Sk, Dh)
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    B, H, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    n_q = Sq // block_q
    n_k = Sk // block_k
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(
        _kernel,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, block_k, Dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, Dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
