"""Pure-jnp oracle for flash attention (GQA-aware, causal)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, sm_scale: float, causal: bool):
    """Naive attention.  q: (B, H, Sq, Dh); k, v: (B, Hkv, Sk, Dh)."""
    B, H, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * sm_scale
    if causal:
        rows = jnp.arange(Sq)[:, None]
        cols = jnp.arange(Sk)[None, :]
        s = jnp.where(rows >= cols, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
