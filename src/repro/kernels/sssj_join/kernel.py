"""Pallas TPU kernel: blocked time-decayed similarity join with pruning.

This is the TPU-native re-derivation of the paper's STR-L2 hot loop
(candidate generation, §5.3–§5.4): for a tile of Q query vectors and a tile
of W window (candidate) vectors it computes the thresholded, time-decayed
score matrix

    S[i, j] = dot(q_i, w_j) · exp(-λ |t_qi - t_wj|)    if ≥ θ and uid order
              0                                         otherwise

with the paper's two pruning mechanisms lifted from item granularity to
tile granularity (see DESIGN.md §2):

  * **time filtering** — if ``max_ij exp(-λΔt_ij) < θ`` the whole tile is
    dead (``dot ≤ 1``) and the k-loop is never entered; this also covers
    ring-buffer slots that are empty (uid < 0) and pairs excluded by the
    uid order mask, which are folded into the decay matrix as zeros;
  * **ℓ2 suffix bound (Cauchy–Schwarz)** — the feature dimension is
    processed in chunks; after chunk k, the unseen remainder is bounded by
    ``‖q_i^{>k}‖ · ‖w_j^{>k}‖`` (precomputed suffix norms); when the bound
    says no pair in the tile can reach θ, the k-loop exits early.  This is
    exactly the paper's ``rs2``/``l2bound`` pruning, applied per tile.

Grid: ``(n_q_tiles, n_w_tiles)``.  Each program owns one (BQ, BW) output
tile; the full feature dimension of both tiles is staged in VMEM and
consumed chunk by chunk so the early exit saves real MXU work.

Two emission variants share the score computation (``_tile_scores``):

  * :func:`sssj_join_kernel_call` — the PR-1 dense variant: writes the full
    thresholded ``(Q, W)`` score tile to HBM plus per-tile emit counts.
    Retained as the ``emit_dense`` oracle path.
  * :func:`sssj_join_candidates_kernel_call` — level 1 of the hierarchical
    compaction (DESIGN.md §3): each program selects its own ≥ θ entries
    into a fixed ``(tile_k,)`` candidate buffer of (in-tile index, score)
    pairs via a rank scan (row-wise cumulative counts) + branchless binary
    search — **no sort, and no dense tile ever leaves VMEM**.  Dead tiles
    (the common case under time filtering) write only a zero count and the
    inert-slot fill, so HBM output is ``O(n_tiles · tile_k)`` instead of
    ``4·Q·W`` bytes.  A per-row hit bitmap (exact even when ``tile_k``
    overflows) rides along for the O(B) match-mask consumers.

VMEM footprint per program ≈ (BQ + BW)·d·bytes + BQ·BW·4 (+ tile_k·8 for
the candidate variant).  With the default BQ = BW = 128, d ≤ 8192 this
stays within a v5e core's ~16 MB VMEM budget for bf16 inputs; wider models
should shrink BQ/BW or shard d (see ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sssj_join_kernel_call", "sssj_join_candidates_kernel_call"]

NEG_UID = -1  # uid marking empty / padded slots


def _tile_scores(
    q_ref, w_ref, tq_ref, tw_ref, uq_ref, uw_ref, sqq_ref, sqw_ref,
    *, theta: float, lam: float, chunk_d: int, n_chunks: int,
    bq: int, bw: int,
    sid_q_ref=None, sid_w_ref=None, th_ref=None, lm_ref=None,
    gate_ref=None,
):
    """Shared per-tile score computation: thresholded decayed similarities
    for one (BQ, BW) tile, with tile-level time filtering and the chunked
    ℓ2 early exit.  Returns ``(emitted (BQ, BW) f32, k_final () i32)``.

    The optional multi-tenant refs (DESIGN.md §9) fold a stream-equality
    mask into the order mask (``sid_q == sid_w``; cross-stream pairs never
    score) and replace the static (θ, λ) with per-query-row values looked
    up from the tenant table — the query row's stream is the pair's stream,
    so query-side values govern the pair.  Both prunes survive: the decay
    matrix uses the row's λ, and every "≥ θ" check becomes row-wise
    (``any(x ≥ θ_row)``), which for a scalar θ is the same predicate the
    single-tenant kernel used.
    """
    f32 = jnp.float32
    tq = tq_ref[:, 0].astype(f32)              # (BQ,)
    tw = tw_ref[:, 0].astype(f32)              # (BW,)
    uq = uq_ref[:, 0]                          # (BQ,) int32
    uw = uw_ref[:, 0]                          # (BW,) int32
    if th_ref is None:
        th = theta                             # scalar broadcast
        lam_col = lam
    else:
        th = th_ref[:, 0].astype(f32)[:, None]   # (BQ, 1)
        lam_col = lm_ref[:, 0].astype(f32)[:, None]

    dt = jnp.abs(tq[:, None] - tw[None, :])
    decay = jnp.exp(-lam_col * dt)             # (BQ, BW)
    # uid-order mask: join each pair once (query strictly newer), and drop
    # empty ring slots / padding (uid < 0).  Folded into the decay matrix so
    # the tile-level time filter below covers all masking at once.
    order = (uw[None, :] >= 0) & (uq[:, None] > uw[None, :])
    if sid_q_ref is not None:
        order &= sid_q_ref[:, 0][:, None] == sid_w_ref[:, 0][None, :]
    decay = jnp.where(order, decay, 0.0)

    # --- time filtering at tile granularity (paper §3 / §6.2) ---
    tile_alive = jnp.any(decay >= th)          # dot ≤ 1 ⇒ decayed ≤ decay
    if gate_ref is not None:
        # pre-launch L2/prefix gate (DESIGN.md §13): the strip-summary
        # bound already proved this tile cannot reach any row's θ, so the
        # chunk loop never starts (k_final = 0, like a time-dead tile)
        tile_alive &= gate_ref[0, 0] > 0

    def cond(state):
        k, _, live = state
        return live & (k < n_chunks)

    def body(state):
        k, acc, _ = state
        qk = q_ref[:, pl.ds(k * chunk_d, chunk_d)].astype(f32)
        wk = w_ref[:, pl.ds(k * chunk_d, chunk_d)].astype(f32)
        acc = acc + jax.lax.dot_general(
            qk, wk,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=f32,
        )
        # --- ℓ2 suffix bound (paper's rs2 / l2bound at tile granularity) ---
        sq = jax.lax.dynamic_slice_in_dim(sqq_ref[...], k, 1, 1)[:, 0]   # (BQ,)
        sw = jax.lax.dynamic_slice_in_dim(sqw_ref[...], k, 1, 1)[:, 0]   # (BW,)
        ub = (acc + sq[:, None] * sw[None, :]) * decay
        live = jnp.any(ub >= th)
        return k + 1, acc, live

    acc0 = jnp.zeros((bq, bw), dtype=f32)
    k_final, acc, _ = jax.lax.while_loop(cond, body, (0, acc0, tile_alive))

    scores = acc * decay
    emitted = jnp.where(scores >= th, scores, 0.0)
    return emitted, k_final


def _kernel(
    q_ref, w_ref, tq_ref, tw_ref, uq_ref, uw_ref, sqq_ref, sqw_ref,
    out_ref, iters_ref, counts_ref,
    *, theta: float, lam: float, chunk_d: int, n_chunks: int,
):
    bq, bw = out_ref.shape
    emitted, k_final = _tile_scores(
        q_ref, w_ref, tq_ref, tw_ref, uq_ref, uw_ref, sqq_ref, sqw_ref,
        theta=theta, lam=lam, chunk_d=chunk_d, n_chunks=n_chunks,
        bq=bq, bw=bw,
    )
    out_ref[...] = emitted
    iters_ref[0, 0] = k_final
    # stage 1 of pair compaction: how many entries this tile will emit
    counts_ref[0, 0] = jnp.sum((emitted > 0.0).astype(jnp.int32))


def _cand_kernel(
    q_ref, w_ref, tq_ref, tw_ref, uq_ref, uw_ref, sqq_ref, sqw_ref,
    *refs,
    theta: float, lam: float, chunk_d: int, n_chunks: int, tile_k: int,
    multi: bool = False,
    gated: bool = False,
):
    """Level-1 hierarchical compaction: select this tile's ≥ θ entries.

    Rank assignment is a scan (row-wise cumulative counts + a row-offset
    scan), and slot filling is a branchless binary search over the
    monotone flattened count vector — the inverse permutation of an
    exclusive-scan scatter, expressed as a gather because TPU (and XLA CPU)
    handle a ``tile_k``-sized gather far better than a ``BQ·BW``-sized
    scatter.  Dead tiles skip the search entirely.

    With ``multi=True`` four extra input refs precede the outputs —
    per-row stream ids (query/window) and per-query-row (θ, λ) — and the
    stream-equality mask joins the masking stack (see ``_tile_scores``).
    """
    if multi:
        sid_q_ref, sid_w_ref, th_ref, lm_ref = refs[:4]
        refs = refs[4:]
    else:
        sid_q_ref = sid_w_ref = th_ref = lm_ref = None
    if gated:
        gate_ref, *refs = refs
    else:
        gate_ref = None
    idx_ref, score_ref, emitted_ref, rowhits_ref, iters_ref = refs
    bq = q_ref.shape[0]
    bw = w_ref.shape[0]
    n = bq * bw
    emitted, k_final = _tile_scores(
        q_ref, w_ref, tq_ref, tw_ref, uq_ref, uw_ref, sqq_ref, sqw_ref,
        theta=theta, lam=lam, chunk_d=chunk_d, n_chunks=n_chunks,
        bq=bq, bw=bw,
        sid_q_ref=sid_q_ref, sid_w_ref=sid_w_ref, th_ref=th_ref,
        lm_ref=lm_ref, gate_ref=gate_ref,
    )
    iters_ref[0, 0] = k_final

    m = (emitted > 0.0).astype(jnp.int32)          # (BQ, BW)
    crow = jnp.cumsum(m, axis=1)                   # inclusive within-row
    row_tot = crow[:, -1:]                         # (BQ, 1)
    rowhits_ref[0, 0, :] = (row_tot[:, 0] > 0).astype(jnp.int32)
    row_base = jnp.cumsum(row_tot, axis=0) - row_tot   # exclusive over rows
    count = row_base[-1, 0] + row_tot[-1, 0]
    emitted_ref[0, 0] = count

    @pl.when(count == 0)
    def _():
        idx_ref[0, 0, :] = jnp.full((tile_k,), -1, jnp.int32)
        score_ref[0, 0, :] = jnp.zeros((tile_k,), jnp.float32)

    @pl.when(count > 0)
    def _():
        # c_flat[e] = # of emitted entries at flat positions ≤ e (row-major);
        # monotone non-decreasing, so "the slot-s entry lives at the first e
        # with c_flat[e] ≥ s+1" is a binary search, not a sort.
        c_flat = (crow + row_base).reshape(n)
        target = jax.lax.broadcasted_iota(jnp.int32, (tile_k, 1), 0)[:, 0] + 1
        lo = jnp.zeros((tile_k,), jnp.int32)
        step = 1
        while step < n:
            step <<= 1
        while step:
            cand = lo + step
            # c_flat[cand - 1] < target ⇒ the answer lies at or past cand
            cval = c_flat[jnp.minimum(cand, n) - 1]
            lo = jnp.where((cand <= n) & (cval < target), cand, lo)
            step >>= 1
        kept = jnp.minimum(count, tile_k)
        valid = target <= kept                     # i.e. slot < kept
        src = jnp.minimum(lo, n - 1)
        idx_ref[0, 0, :] = jnp.where(valid, src, -1).astype(jnp.int32)
        score_ref[0, 0, :] = jnp.where(
            valid, emitted.reshape(n)[src], 0.0
        ).astype(jnp.float32)


def _join_in_specs(block_q: int, block_w: int, d: int, n_chunks: int):
    return [
        pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),        # q
        pl.BlockSpec((block_w, d), lambda i, j: (j, 0)),        # w
        pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),        # tq
        pl.BlockSpec((block_w, 1), lambda i, j: (j, 0)),        # tw
        pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),        # uq
        pl.BlockSpec((block_w, 1), lambda i, j: (j, 0)),        # uw
        pl.BlockSpec((block_q, n_chunks), lambda i, j: (i, 0)), # sqq
        pl.BlockSpec((block_w, n_chunks), lambda i, j: (j, 0)), # sqw
    ]


def sssj_join_kernel_call(
    q: jax.Array,        # (Q, d)
    w: jax.Array,        # (W, d)
    tq: jax.Array,       # (Q, 1) f32
    tw: jax.Array,       # (W, 1) f32
    uq: jax.Array,       # (Q, 1) i32
    uw: jax.Array,       # (W, 1) i32
    sqq: jax.Array,      # (Q, n_chunks) f32 suffix norms after each chunk
    sqw: jax.Array,      # (W, n_chunks) f32
    *,
    theta: float,
    lam: float,
    block_q: int,
    block_w: int,
    chunk_d: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dense-emission pallas_call; shapes must be padded to block multiples.

    Returns ``(scores (Q, W), iters (nQ, nW), counts (nQ, nW))`` where
    ``counts`` is the per-tile number of emitted (≥ θ) entries.
    """
    Q, d = q.shape
    W, _ = w.shape
    n_chunks = d // chunk_d
    grid = (Q // block_q, W // block_w)

    kernel = functools.partial(
        _kernel, theta=theta, lam=lam, chunk_d=chunk_d, n_chunks=n_chunks
    )
    out_shape = [
        jax.ShapeDtypeStruct((Q, W), jnp.float32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((block_q, block_w), lambda i, j: (i, j)),
        pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        pl.BlockSpec((1, 1), lambda i, j: (i, j)),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_join_in_specs(block_q, block_w, d, n_chunks),
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(q, w, tq, tw, uq, uw, sqq, sqw)


def sssj_join_candidates_kernel_call(
    q: jax.Array,        # (Q, d)
    w: jax.Array,        # (W, d)
    tq: jax.Array,       # (Q, 1) f32
    tw: jax.Array,       # (W, 1) f32
    uq: jax.Array,       # (Q, 1) i32
    uw: jax.Array,       # (W, 1) i32
    sqq: jax.Array,      # (Q, n_chunks) f32
    sqw: jax.Array,      # (W, n_chunks) f32
    *,
    theta: float,
    lam: float,
    block_q: int,
    block_w: int,
    chunk_d: int,
    tile_k: int,
    interpret: bool,
    sq: jax.Array = None,       # (Q, 1) i32 stream ids (multi-tenant)
    sw: jax.Array = None,       # (W, 1) i32
    theta_q: jax.Array = None,  # (Q, 1) f32 per-row θ
    lam_q: jax.Array = None,    # (Q, 1) f32 per-row λ
    gate: jax.Array = None,     # (nQ, nW) i32 pre-launch gate (0 = dead)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Hierarchical (level-1) pallas_call; no dense ``(Q, W)`` output exists.

    Returns ``(cand_idx (nQ, nW, tile_k) i32 in-tile row-major flat index or
    -1, cand_score (nQ, nW, tile_k) f32, emitted (nQ, nW) i32 true per-tile
    ≥ θ counts, row_hits (nQ, nW, block_q) i32 0/1, iters (nQ, nW) i32)``.

    The multi-tenant lanes (all four or none) ride as extra ``(·, 1)``
    inputs with the same block specs as the timestamp lanes.
    """
    Q, d = q.shape
    W, _ = w.shape
    n_chunks = d // chunk_d
    nq, nw = Q // block_q, W // block_w
    grid = (nq, nw)
    multi = sq is not None
    if multi and theta_q is None:
        # stream lanes without per-row (θ, λ) — uniform tenants: the kernel
        # takes the four lanes together, so broadcast the static scalars
        # (numerically identical to the scalar path)
        theta_q = jnp.full((Q, 1), theta, jnp.float32)
        lam_q = jnp.full((Q, 1), lam, jnp.float32)

    kernel = functools.partial(
        _cand_kernel, theta=theta, lam=lam, chunk_d=chunk_d,
        n_chunks=n_chunks, tile_k=tile_k, multi=multi,
        gated=gate is not None,
    )
    in_specs = _join_in_specs(block_q, block_w, d, n_chunks)
    inputs = [q, w, tq, tw, uq, uw, sqq, sqw]
    if multi:
        in_specs += [
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),  # sq
            pl.BlockSpec((block_w, 1), lambda i, j: (j, 0)),  # sw
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),  # theta_q
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),  # lam_q
        ]
        inputs += [sq, sw, theta_q, lam_q]
    if gate is not None:
        in_specs += [pl.BlockSpec((1, 1), lambda i, j: (i, j))]
        inputs += [gate]
    out_shape = [
        jax.ShapeDtypeStruct((nq, nw, tile_k), jnp.int32),
        jax.ShapeDtypeStruct((nq, nw, tile_k), jnp.float32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
        jax.ShapeDtypeStruct((nq, nw, block_q), jnp.int32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, tile_k), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, 1, tile_k), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        pl.BlockSpec((1, 1, block_q), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, 1), lambda i, j: (i, j)),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
