"""Pallas TPU kernel: blocked time-decayed similarity join with pruning.

This is the TPU-native re-derivation of the paper's STR-L2 hot loop
(candidate generation, §5.3–§5.4): for a tile of Q query vectors and a tile
of W window (candidate) vectors it computes the thresholded, time-decayed
score matrix

    S[i, j] = dot(q_i, w_j) · exp(-λ |t_qi - t_wj|)    if ≥ θ and uid order
              0                                         otherwise

with the paper's two pruning mechanisms lifted from item granularity to
tile granularity (see DESIGN.md §2):

  * **time filtering** — if ``max_ij exp(-λΔt_ij) < θ`` the whole tile is
    dead (``dot ≤ 1``) and the k-loop is never entered; this also covers
    ring-buffer slots that are empty (uid < 0) and pairs excluded by the
    uid order mask, which are folded into the decay matrix as zeros;
  * **ℓ2 suffix bound (Cauchy–Schwarz)** — the feature dimension is
    processed in chunks; after chunk k, the unseen remainder is bounded by
    ``‖q_i^{>k}‖ · ‖w_j^{>k}‖`` (precomputed suffix norms); when the bound
    says no pair in the tile can reach θ, the k-loop exits early.  This is
    exactly the paper's ``rs2``/``l2bound`` pruning, applied per tile.

Grid: ``(n_q_tiles, n_w_tiles)``.  Each program owns one (BQ, BW) output
tile; the full feature dimension of both tiles is staged in VMEM and
consumed chunk by chunk so the early exit saves real MXU work.

VMEM footprint per program ≈ (BQ + BW)·d·bytes + BQ·BW·4.  With the default
BQ = BW = 128, d ≤ 8192 this stays within a v5e core's ~16 MB VMEM budget
for bf16 inputs; wider models should shrink BQ/BW or shard d (see ops.py).

Outputs: the score tile, a per-tile iteration count (number of d-chunks
actually executed) — the TPU analogue of the paper's "entries traversed"
instrumentation (Figs. 2/6) — and a per-tile count of emitted (≥ θ) entries,
which is stage 1 of the on-device pair compaction pipeline (DESIGN.md §3):
count per tile → exclusive scan for offsets → gather into a fixed-capacity
pair buffer, so only O(pairs) bytes ever cross to the host.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sssj_join_kernel_call"]

NEG_UID = -1  # uid marking empty / padded slots


def _kernel(
    q_ref, w_ref, tq_ref, tw_ref, uq_ref, uw_ref, sqq_ref, sqw_ref,
    out_ref, iters_ref, counts_ref,
    *, theta: float, lam: float, chunk_d: int, n_chunks: int,
):
    f32 = jnp.float32
    tq = tq_ref[:, 0].astype(f32)              # (BQ,)
    tw = tw_ref[:, 0].astype(f32)              # (BW,)
    uq = uq_ref[:, 0]                          # (BQ,) int32
    uw = uw_ref[:, 0]                          # (BW,) int32

    dt = jnp.abs(tq[:, None] - tw[None, :])
    decay = jnp.exp(-lam * dt)                 # (BQ, BW)
    # uid-order mask: join each pair once (query strictly newer), and drop
    # empty ring slots / padding (uid < 0).  Folded into the decay matrix so
    # the tile-level time filter below covers all masking at once.
    order = (uw[None, :] >= 0) & (uq[:, None] > uw[None, :])
    decay = jnp.where(order, decay, 0.0)

    # --- time filtering at tile granularity (paper §3 / §6.2) ---
    tile_alive = jnp.max(decay) >= theta       # dot ≤ 1 ⇒ decayed ≤ decay

    bq, bw = out_ref.shape

    def cond(state):
        k, _, live = state
        return live & (k < n_chunks)

    def body(state):
        k, acc, _ = state
        qk = q_ref[:, pl.ds(k * chunk_d, chunk_d)].astype(f32)
        wk = w_ref[:, pl.ds(k * chunk_d, chunk_d)].astype(f32)
        acc = acc + jax.lax.dot_general(
            qk, wk,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=f32,
        )
        # --- ℓ2 suffix bound (paper's rs2 / l2bound at tile granularity) ---
        sq = jax.lax.dynamic_slice_in_dim(sqq_ref[...], k, 1, 1)[:, 0]   # (BQ,)
        sw = jax.lax.dynamic_slice_in_dim(sqw_ref[...], k, 1, 1)[:, 0]   # (BW,)
        ub = (acc + sq[:, None] * sw[None, :]) * decay
        live = jnp.max(ub) >= theta
        return k + 1, acc, live

    acc0 = jnp.zeros((bq, bw), dtype=f32)
    k_final, acc, _ = jax.lax.while_loop(cond, body, (0, acc0, tile_alive))

    scores = acc * decay
    emitted = jnp.where(scores >= theta, scores, 0.0)
    out_ref[...] = emitted
    iters_ref[0, 0] = k_final
    # stage 1 of pair compaction: how many entries this tile will emit
    counts_ref[0, 0] = jnp.sum((emitted > 0.0).astype(jnp.int32))


def sssj_join_kernel_call(
    q: jax.Array,        # (Q, d)
    w: jax.Array,        # (W, d)
    tq: jax.Array,       # (Q, 1) f32
    tw: jax.Array,       # (W, 1) f32
    uq: jax.Array,       # (Q, 1) i32
    uw: jax.Array,       # (W, 1) i32
    sqq: jax.Array,      # (Q, n_chunks) f32 suffix norms after each chunk
    sqw: jax.Array,      # (W, n_chunks) f32
    *,
    theta: float,
    lam: float,
    block_q: int,
    block_w: int,
    chunk_d: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Raw pallas_call; shapes must already be padded to block multiples.

    Returns ``(scores (Q, W), iters (nQ, nW), counts (nQ, nW))`` where
    ``counts`` is the per-tile number of emitted (≥ θ) entries.
    """
    Q, d = q.shape
    W, _ = w.shape
    n_chunks = d // chunk_d
    grid = (Q // block_q, W // block_w)

    kernel = functools.partial(
        _kernel, theta=theta, lam=lam, chunk_d=chunk_d, n_chunks=n_chunks
    )
    out_shape = [
        jax.ShapeDtypeStruct((Q, W), jnp.float32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
    ]
    in_specs = [
        pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),        # q
        pl.BlockSpec((block_w, d), lambda i, j: (j, 0)),        # w
        pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),        # tq
        pl.BlockSpec((block_w, 1), lambda i, j: (j, 0)),        # tw
        pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),        # uq
        pl.BlockSpec((block_w, 1), lambda i, j: (j, 0)),        # uw
        pl.BlockSpec((block_q, n_chunks), lambda i, j: (i, 0)), # sqq
        pl.BlockSpec((block_w, n_chunks), lambda i, j: (j, 0)), # sqw
    ]
    out_specs = [
        pl.BlockSpec((block_q, block_w), lambda i, j: (i, j)),
        pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        pl.BlockSpec((1, 1), lambda i, j: (i, j)),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(q, w, tq, tw, uq, uw, sqq, sqw)
