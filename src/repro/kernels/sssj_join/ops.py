"""Public jit'd wrappers for the SSSJ blocked-join kernel.

Handles padding to block multiples, suffix-norm precomputation (the ℓ2
pruning bounds), backend auto-detection (interpret mode off-TPU), routing
of sub-block inputs through the jnp reference (a `pallas_call` on a
smaller-than-one-block problem only pays padding + launch overhead), and
unpadding of the outputs.

Two join surfaces:

  * :func:`sssj_join_tiles` — dense emission: the thresholded ``(Q, W)``
    score matrix plus per-tile telemetry.  This is the PR-1 path, retained
    as the ``emit_dense`` oracle; it materializes O(Q·W) bytes.
  * :func:`sssj_join_candidates` — hierarchical emission (DESIGN.md §3):
    per-tile ``(tile_k,)`` candidate buffers with true-emit counts and a
    per-row hit mask.  Three interchangeable implementations produce
    bit-identical candidate buffers:

      - ``"pallas"`` — the level-1 select inside the TPU kernel
        (``kernel.sssj_join_candidates_kernel_call``); the dense tile
        never leaves VMEM.
      - ``"scan"``   — a ``lax.scan`` over window tiles in plain jnp: one
        ``(Q, block_w)`` score block live at a time, selected per tile and
        discarded.  The compiled CPU/GPU default — no interpret-mode
        overhead and still no ``(Q, W)`` allocation.
      - ``"dense"``  — the jnp oracle: full ``(Q, W)`` ref scores, then
        :func:`repro.kernels.sssj_join.compact.tile_candidates`.  Used for
        sub-block inputs and as the ground truth in tests.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .compact import PairCandidates, tile_candidates, tile_emit_counts
from .gate import StripSummary, strip_gate
from .kernel import (
    NEG_UID,
    sssj_join_candidates_kernel_call,
    sssj_join_kernel_call,
)
from .ref import sssj_join_ref

__all__ = [
    "JoinCandidates",
    "sssj_join_candidates",
    "sssj_join_scores",
    "sssj_join_tiles",
    "suffix_chunk_norms",
    "NEG_UID",
]


def suffix_chunk_norms(x: jax.Array, chunk_d: int) -> jax.Array:
    """``out[i, k] = ‖x_i restricted to chunks > k‖`` (f32, (n, n_chunks)).

    This is the per-vector data the paper's L2 index stores in its posting
    entries (prefix magnitudes ‖x'_j‖), reorganized for chunked evaluation:
    after the kernel has accumulated chunks 0..k, the unseen remainder of
    the dot product is bounded by ``out_q[i, k] * out_w[j, k]``.
    """
    n, d = x.shape
    n_chunks = d // chunk_d
    sq = (x.astype(jnp.float32) ** 2).reshape(n, n_chunks, chunk_d).sum(-1)
    # reverse-exclusive cumulative sum over chunks
    suffix_sq = jnp.flip(jnp.cumsum(jnp.flip(sq, axis=1), axis=1), axis=1)
    suffix_excl = jnp.concatenate(
        [suffix_sq[:, 1:], jnp.zeros((n, 1), jnp.float32)], axis=1
    )
    return jnp.sqrt(suffix_excl)


def _pad_rows(x: jax.Array, mult: int, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1), constant_values=fill)


@functools.partial(
    jax.jit,
    static_argnames=(
        "theta", "lam", "block_q", "block_w", "chunk_d", "interpret", "use_ref"
    ),
)
def sssj_join_tiles(
    q: jax.Array,
    w: jax.Array,
    tq: jax.Array,
    tw: jax.Array,
    uq: jax.Array,
    uw: jax.Array,
    *,
    theta: float,
    lam: float,
    block_q: int = 128,
    block_w: int = 128,
    chunk_d: int = 128,
    interpret: Optional[bool] = None,
    use_ref: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked time-decayed similarity join with per-tile telemetry.

    Args:
      q:  (Q, d) query vectors (unit-normalized; f32 or bf16).
      w:  (W, d) window vectors.
      tq: (Q,) or (Q, 1) query timestamps.
      tw: (W,) window timestamps.
      uq: (Q,) query uids (monotone stream counters).
      uw: (W,) window uids; negative marks empty ring slots.
      theta, lam: SSSJ parameters.
      use_ref: route through the pure-jnp oracle instead of the kernel.
        Inputs smaller than one block (Q < block_q, W < block_w, or
        d < chunk_d) are auto-routed through the reference as well — the
        kernel would spend its time on padding for them.

    Returns:
      scores: (Q, W) f32 — decayed similarity where ≥ θ (masked by uid
        order), 0 elsewhere.
      iters:  (nQ, nW) i32 — d-chunks executed per tile (pruning telemetry);
        all-`n_chunks` on the ref path.
      counts: (nQ, nW) i32 — emitted (≥ θ) entries per tile, stage 1 of the
        on-device pair compaction (see compact.py).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tq = tq.reshape(-1, 1).astype(jnp.float32)
    tw = tw.reshape(-1, 1).astype(jnp.float32)
    uq = uq.reshape(-1, 1).astype(jnp.int32)
    uw = uw.reshape(-1, 1).astype(jnp.int32)

    Q, d = q.shape
    W, _ = w.shape
    # ref fallback for unaligned tiny inputs: anything smaller than a single
    # kernel block would be all padding, so the dense jnp oracle is cheaper
    if Q < block_q or W < block_w or d < chunk_d:
        use_ref = True
    if use_ref:
        scores = sssj_join_ref(q, w, tq, tw, uq, uw, theta=theta, lam=lam)
        n_chunks = max(d // chunk_d, 1)
        iters = jnp.full(
            ((Q + block_q - 1) // block_q, (W + block_w - 1) // block_w),
            n_chunks,
            jnp.int32,
        )
        counts = tile_emit_counts(scores, block_q, block_w)
        return scores, iters, counts

    if d % chunk_d != 0:
        pad_d = (-d) % chunk_d
        q = jnp.pad(q, ((0, 0), (0, pad_d)))
        w = jnp.pad(w, ((0, 0), (0, pad_d)))
        d += pad_d

    qp = _pad_rows(q, block_q)
    wp = _pad_rows(w, block_w)
    tqp = _pad_rows(tq, block_q)
    twp = _pad_rows(tw, block_w)
    uqp = _pad_rows(uq, block_q, fill=NEG_UID)
    uwp = _pad_rows(uw, block_w, fill=NEG_UID)
    sqq = suffix_chunk_norms(qp, chunk_d)
    sqw = suffix_chunk_norms(wp, chunk_d)

    scores, iters, counts = sssj_join_kernel_call(
        qp, wp, tqp, twp, uqp, uwp, sqq, sqw,
        theta=theta, lam=lam,
        block_q=block_q, block_w=block_w, chunk_d=chunk_d,
        interpret=interpret,
    )
    return scores[:Q, :W], iters, counts


def sssj_join_scores(*args, **kw) -> tuple[jax.Array, jax.Array]:
    """Back-compat wrapper of :func:`sssj_join_tiles` without tile counts."""
    scores, iters, _ = sssj_join_tiles(*args, **kw)
    return scores, iters


# --------------------------------------------------------------------- #
# hierarchical emission
# --------------------------------------------------------------------- #
class JoinCandidates(NamedTuple):
    """Level-1 join output: per-tile candidates + exact per-row hit mask.

    ``cands`` segments are tiles in (q-tile, w-tile) row-major order, each
    holding its first ``kept`` ≥ θ pairs in within-tile row-major (stream)
    order.  ``row_mask (Q,)`` is exact even when ``tile_k`` overflows: it
    derives from counts, not survivors.  ``iters (nQ, nW)`` is the pruning
    telemetry (d-chunks executed; full count on the jnp impls, which do
    not prune).
    """

    cands: PairCandidates
    row_mask: jax.Array
    iters: jax.Array
    gate_stats: Optional[jax.Array] = None  # (3,) i32 [skipped_time,
    #                                         skipped_l2, strips_survived];
    #                                         zeros when no gate ran


def _kernel_candidates(cand_idx, cand_score, emitted, uqp, uwp, block_q, block_w):
    """Decode the kernel's in-tile flat indices into uid-level candidates."""
    nq, nw, K = cand_idx.shape
    valid = cand_idx >= 0
    idx = jnp.maximum(cand_idx, 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (nq, nw, K), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (nq, nw, K), 1)
    qi = ti * block_q + idx // block_w
    wi = tj * block_w + idx % block_w
    uid_a = jnp.where(valid, uqp[qi], -1)
    uid_b = jnp.where(valid, uwp[wi], -1)
    t = nq * nw
    return PairCandidates(
        uid_a=uid_a.reshape(t, K),
        uid_b=uid_b.reshape(t, K),
        score=jnp.where(valid, cand_score, 0.0).reshape(t, K),
        kept=jnp.minimum(emitted, K).reshape(t),
        emitted=emitted.reshape(t),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "theta", "lam", "tile_k", "block_q", "block_w", "chunk_d",
        "impl", "interpret",
    ),
)
def sssj_join_candidates(
    q: jax.Array,
    w: jax.Array,
    tq: jax.Array,
    tw: jax.Array,
    uq: jax.Array,
    uw: jax.Array,
    *,
    theta: float,
    lam: float,
    tile_k: int = 256,
    block_q: int = 128,
    block_w: int = 128,
    chunk_d: int = 128,
    impl: Optional[str] = None,
    interpret: Optional[bool] = None,
    sq: Optional[jax.Array] = None,
    sw: Optional[jax.Array] = None,
    theta_q: Optional[jax.Array] = None,
    lam_q: Optional[jax.Array] = None,
    summary: Optional[StripSummary] = None,
) -> JoinCandidates:
    """Blocked join with hierarchical (level-1) emission — no dense matrix.

    Args mirror :func:`sssj_join_tiles`; ``tile_k`` caps the candidates a
    single (block_q, block_w) tile may keep (overflow is counted in
    ``cands.emitted - cands.kept``, never silent).  ``impl`` picks the
    implementation (``"pallas"`` / ``"scan"`` / ``"dense"``, see module
    docstring); ``None`` auto-selects: the Pallas kernel on TPU, the
    compiled tile-scan elsewhere.  Sub-block inputs always take the dense
    jnp oracle — same candidate buffers, and the dense matrix they briefly
    materialize is smaller than one kernel tile.

    Multi-tenant lanes (DESIGN.md §9, honored identically by all three
    implementations):

      * ``sq (Q,)`` / ``sw (W,)`` — stream ids; a stream-equality mask is
        folded into the uid-order mask, so cross-stream pairs never emit;
      * ``theta_q (Q,)`` / ``lam_q (Q,)`` — optional per-query-row (θ, λ)
        looked up from the tenant table (pass both or neither).  The
        stream-equality mask makes the query row's stream the pair's
        stream, so query-side values govern the pair; the static
        ``theta``/``lam`` then only seed pruning defaults.

    L2/prefix gate (DESIGN.md §13): ``summary`` optionally carries the
    window's per-strip :class:`~repro.kernels.sssj_join.gate.StripSummary`
    (``n_strips = ceil(W / block_w)`` rows, maintained by the engine's
    write path).  When present, an admissible pre-launch bound gates every
    (query-tile × strip): the ``"scan"`` impl walks only surviving strips
    (a compacted gather — interior dead strips cost nothing), and the
    ``"pallas"`` impl folds the gate into the kernel's tile-alive predicate
    so gated-off programs skip the chunk loop.  The ``"dense"`` oracle
    ignores it.  Gating never changes emitted candidates — the bound
    certifies that a skipped tile cannot reach any row's θ.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "scan"
    if (theta_q is None) != (lam_q is None):
        raise ValueError("theta_q and lam_q must be passed together")
    if (sq is None) != (sw is None):
        raise ValueError("sq and sw must be passed together")
    if theta_q is not None and sq is None:
        raise ValueError("per-row (theta_q, lam_q) requires stream lanes")
    tq = tq.reshape(-1).astype(jnp.float32)
    tw = tw.reshape(-1).astype(jnp.float32)
    uq = uq.reshape(-1).astype(jnp.int32)
    uw = uw.reshape(-1).astype(jnp.int32)
    if sq is not None:
        sq = sq.reshape(-1).astype(jnp.int32)
        sw = sw.reshape(-1).astype(jnp.int32)
    if theta_q is not None:
        theta_q = theta_q.reshape(-1).astype(jnp.float32)
        lam_q = lam_q.reshape(-1).astype(jnp.float32)
    # pruning scalars must come from the UNPADDED per-row tables: row
    # padding below uses inert fills (θ=2 can never emit, λ=0 never decays)
    # which would otherwise loosen the min-based strip/tile bounds.  Under
    # the sharded engine this call runs inside shard_map with q/theta_q/
    # lam_q REPLICATED and only w/sw sharded — every shard therefore
    # derives the same (min θ, min λ) over the same rows, and a strip
    # skipped on one shard is skipped because it is provably below every
    # row's threshold, exactly as on a single device (DESIGN.md §10)
    th_min = theta if theta_q is None else jnp.min(theta_q)
    lam_min = lam if lam_q is None else jnp.min(lam_q)
    # time extremes for the strip filters/gate, also from the UNPADDED
    # batch: _pad_rows fills tq with 0.0, which would pin tq_lo to 0 and
    # disable the older-than-horizon bound for any ragged Q (padded rows
    # carry uid = -1 and can never emit, so excluding them is sound)
    tq_lo, tq_hi = jnp.min(tq), jnp.max(tq)
    no_gate_stats = jnp.zeros((3,), jnp.int32)

    Q, d = q.shape
    W, _ = w.shape
    # sub-block inputs take the dense oracle (a kernel/scan launch would be
    # all padding); d < chunk_d only matters to the kernel's d-chunking —
    # the scan impl does not chunk d and stays on its no-dense-matrix path
    if Q < block_q or W < block_w or (d < chunk_d and impl != "scan"):
        impl = "dense"

    if impl == "dense":
        scores = sssj_join_ref(
            q, w, tq[:, None], tw[:, None], uq[:, None], uw[:, None],
            theta=theta, lam=lam,
            sq=None if sq is None else sq[:, None],
            sw=None if sw is None else sw[:, None],
            theta_q=None if theta_q is None else theta_q[:, None],
            lam_q=None if lam_q is None else lam_q[:, None],
        )
        cands, row_mask = tile_candidates(
            scores, uq, uw, block_q=block_q, block_w=block_w, tile_k=tile_k
        )
        n_chunks = max(d // chunk_d, 1)
        iters = jnp.full(
            ((Q + block_q - 1) // block_q, (W + block_w - 1) // block_w),
            n_chunks,
            jnp.int32,
        )
        return JoinCandidates(
            cands=cands, row_mask=row_mask, iters=iters,
            gate_stats=no_gate_stats,
        )

    if d % chunk_d != 0:
        pad_d = (-d) % chunk_d
        q = jnp.pad(q, ((0, 0), (0, pad_d)))
        w = jnp.pad(w, ((0, 0), (0, pad_d)))
        d += pad_d
    qp = _pad_rows(q, block_q)
    wp = _pad_rows(w, block_w)
    tqp = _pad_rows(tq, block_q)
    twp = _pad_rows(tw, block_w)
    uqp = _pad_rows(uq, block_q, fill=NEG_UID)
    uwp = _pad_rows(uw, block_w, fill=NEG_UID)
    # inert fills: padded rows carry uid = -1 so they can never emit; the
    # θ/λ fills are chosen so they can't loosen any bound either
    sqp = None if sq is None else _pad_rows(sq, block_q, fill=NEG_UID)
    swp = None if sw is None else _pad_rows(sw, block_w, fill=NEG_UID)
    thp = None if theta_q is None else _pad_rows(theta_q, block_q, fill=2.0)
    lmp = None if lam_q is None else _pad_rows(lam_q, block_q, fill=0.0)
    Qp, Wp = qp.shape[0], wp.shape[0]
    nq, nw = Qp // block_q, Wp // block_w

    # L2/prefix pre-launch gate: one (Qp, n_strips) bound evaluation —
    # ~block_w× cheaper than scoring the strips it can kill
    gate = None
    gate_stats = no_gate_stats
    if summary is not None:
        gate, gate_stats = strip_gate(
            qp, summary, block_q=block_q, chunk_d=chunk_d,
            tq_lo=tq_lo, tq_hi=tq_hi, th_min=th_min, lam_min=lam_min,
            impl="pallas" if impl == "pallas" else "jnp",
            interpret=interpret,
        )

    if impl == "pallas":
        sqq = suffix_chunk_norms(qp, chunk_d)
        sqw = suffix_chunk_norms(wp, chunk_d)
        cand_idx, cand_score, emitted, row_hits, iters = (
            sssj_join_candidates_kernel_call(
                qp, wp, tqp[:, None], twp[:, None],
                uqp[:, None], uwp[:, None], sqq, sqw,
                theta=theta, lam=lam, block_q=block_q, block_w=block_w,
                chunk_d=chunk_d, tile_k=tile_k, interpret=interpret,
                sq=None if sqp is None else sqp[:, None],
                sw=None if swp is None else swp[:, None],
                theta_q=None if thp is None else thp[:, None],
                lam_q=None if lmp is None else lmp[:, None],
                gate=None if gate is None else gate.astype(jnp.int32),
            )
        )
        cands = _kernel_candidates(
            cand_idx, cand_score, emitted, uqp, uwp, block_q, block_w
        )
        row_mask = jnp.any(row_hits > 0, axis=1).reshape(Qp)[:Q]
        return JoinCandidates(
            cands=cands, row_mask=row_mask, iters=iters,
            gate_stats=gate_stats,
        )

    if impl != "scan":
        raise ValueError(f"unknown sssj_join_candidates impl {impl!r}")

    # --- "scan": one (Qp, block_w) score block live at a time ----------- #
    w_tiles = wp.reshape(nw, block_w, d)
    tw_tiles = twp.reshape(nw, block_w)
    uw_tiles = uwp.reshape(nw, block_w)
    sw_tiles = None if swp is None else swp.reshape(nw, block_w)
    qf = qp.astype(jnp.float32)
    tq2 = tqp.astype(jnp.float32)
    n_chunks = d // chunk_d

    def strip(s):
        """Score one window column strip and select its tile candidates."""
        wt = jax.lax.dynamic_index_in_dim(w_tiles, s, 0, keepdims=False)
        twt = jax.lax.dynamic_index_in_dim(tw_tiles, s, 0, keepdims=False)
        uwt = jax.lax.dynamic_index_in_dim(uw_tiles, s, 0, keepdims=False)
        sims = qf @ wt.astype(jnp.float32).T                       # (Qp, BW)
        lam_col = lam if lmp is None else lmp[:, None]
        dec = sims * jnp.exp(-lam_col * jnp.abs(tq2[:, None] - twt[None, :]))
        order = (uwt[None, :] >= 0) & (uqp[:, None] > uwt[None, :])
        if sw_tiles is not None:
            swt = jax.lax.dynamic_index_in_dim(sw_tiles, s, 0, keepdims=False)
            order &= sqp[:, None] == swt[None, :]
        thr = theta if thp is None else thp[:, None]
        dec = jnp.where(order & (dec >= thr), dec, 0.0)
        return tile_candidates(
            dec, uqp, uwt, block_q=block_q, block_w=block_w, tile_k=tile_k
        )

    # Strip-level time filter (paper §3, the kernel's first prune, at
    # column-strip granularity): a lower bound on min |Δt| from the strips'
    # time extremes.  Empty ring slots carry t = +3e30, so a fully-empty
    # strip is dead by construction; unit vectors ⇒ dot ≤ 1 ⇒
    # score ≤ exp(-λ·Δt).  With per-row (θ, λ) the scalar bound uses
    # (min θ, min λ), which upper-bounds every row's score requirement.
    uw_max = jnp.max(uw_tiles, axis=1)
    newest = jnp.argmax(uw_max).astype(jnp.int32)
    dist = (newest - jnp.arange(nw, dtype=jnp.int32)) % nw
    if gate is None:
        tw_min = jnp.min(tw_tiles, axis=1)                         # (nw,)
        tw_max = jnp.max(tw_tiles, axis=1)
        dt_lb = jnp.maximum(0.0, jnp.maximum(tq_lo - tw_max, tw_min - tq_hi))
        alive = (jnp.exp(-lam_min * dt_lb) >= th_min) & (uw_max >= 0)
        # Cursor-anchored live range (ROADMAP strip-skipping item): ring
        # writes are sequential and uids monotone, so the newest strip is
        # the one holding the max uid and live strips cluster within the
        # τ-horizon just behind it.  Walking ``dist`` strips back from the
        # newest covers every flagged-alive strip (``n_live`` is defined as
        # exactly that cover), so the sweep costs O(live strips), not
        # O(n_strips) — an all-dead batch runs zero strip iterations
        # instead of n_strips `lax.cond` dispatches.  Correctness never
        # depends on the time-ordering: a strip outside the walk has
        # ``alive = False``, i.e. it is provably below θ for every row.
        alive_walk = alive
        iters = jnp.broadcast_to(
            jnp.where(alive, n_chunks, 0)[None, :], (nq, nw)
        ).astype(jnp.int32)
    else:
        # Gated walk: the L2/prefix gate subsumes the raw time filter
        # (its live-masked time extremes are at least as tight) and adds
        # the value bounds, at (q-tile × strip) granularity.  A strip is
        # scored iff ANY query tile admits it.  The walk itself keeps the
        # exact cursor-anchored shape of the ungated branch — do NOT
        # "optimize" this into an argsort-compacted visit list with a
        # ``sum(alive)`` trip count: under ``shard_map`` (check_vma=False)
        # that graph shape miscompiles, silently replicating one shard's
        # walk onto the others (pairs vanish; caught by the sharded quota
        # conformance cells).  Gate-killed strips inside the live range
        # are skipped by the ``lax.cond`` in ``body`` instead — their
        # matmul never runs, they cost one branch dispatch.
        alive_walk = jnp.any(gate, axis=0)                         # (nw,)
        iters = jnp.where(gate, n_chunks, 0).astype(jnp.int32)

    # Cursor-anchored live range (ROADMAP strip-skipping item): ring
    # writes are sequential and uids monotone, so the newest strip is
    # the one holding the max uid and live strips cluster within the
    # τ-horizon just behind it.  Walking ``dist`` strips back from the
    # newest covers every flagged-alive strip (``n_live`` is defined as
    # exactly that cover), so the sweep costs O(live strips), not
    # O(n_strips) — an all-dead batch runs zero strip iterations.
    # Correctness never depends on the time-ordering: a strip outside
    # the walk has ``alive_walk = False``, i.e. it is provably below θ
    # for every row.
    n_live = jnp.max(jnp.where(alive_walk, dist + 1, 0))

    def body(i, acc):
        s = (newest - i) % nw                    # walk newest-first

        def score(acc):
            cands_acc, mask_acc = acc
            cands_t, rm = strip(s)
            cands_acc = jax.tree.map(
                lambda a, x: jax.lax.dynamic_update_index_in_dim(a, x, s, 0),
                cands_acc, cands_t,
            )
            return cands_acc, mask_acc | rm

        if gate is None:
            # interior dead strips are rare on the sequential ring — a
            # branch per strip costs more than the occasional wasted score
            return score(acc)
        return jax.lax.cond(alive_walk[s], score, lambda a: a, acc)

    zeros_seg = jnp.zeros((nw, nq), jnp.int32)
    cands0 = PairCandidates(
        uid_a=jnp.full((nw, nq, tile_k), -1, jnp.int32),
        uid_b=jnp.full((nw, nq, tile_k), -1, jnp.int32),
        score=jnp.zeros((nw, nq, tile_k), jnp.float32),
        kept=zeros_seg, emitted=zeros_seg,
    )
    col_cands, any_mask = jax.lax.fori_loop(
        0, n_live, body, (cands0, jnp.zeros((Qp,), bool))
    )
    # accumulated leaves are (nw, nq, ...): reorder segments to (nq, nw)
    # tile-row-major so all impls emit identical buffers
    def reorder(x):
        return jnp.swapaxes(
            x.reshape((nw, nq) + x.shape[2:]), 0, 1
        ).reshape((nq * nw,) + x.shape[2:])

    cands = jax.tree.map(reorder, col_cands)
    row_mask = any_mask[:Q]
    # ``iters`` (set above per walk flavor) keeps the kernel's telemetry
    # granularity: dead strips/tiles execute zero d-chunks (the strip
    # bound is coarser than the kernel's per-pair decay max, so this may
    # overcount live tiles)
    return JoinCandidates(
        cands=cands, row_mask=row_mask, iters=iters, gate_stats=gate_stats
    )
