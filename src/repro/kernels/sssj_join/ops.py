"""Public jit'd wrappers for the SSSJ blocked-join kernel.

Handles padding to block multiples, suffix-norm precomputation (the ℓ2
pruning bounds), backend auto-detection (interpret mode off-TPU), routing
of sub-block inputs through the jnp reference (a `pallas_call` on a
smaller-than-one-block problem only pays padding + launch overhead), and
unpadding of the outputs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .compact import tile_emit_counts
from .kernel import NEG_UID, sssj_join_kernel_call
from .ref import sssj_join_ref

__all__ = ["sssj_join_scores", "sssj_join_tiles", "suffix_chunk_norms", "NEG_UID"]


def suffix_chunk_norms(x: jax.Array, chunk_d: int) -> jax.Array:
    """``out[i, k] = ‖x_i restricted to chunks > k‖`` (f32, (n, n_chunks)).

    This is the per-vector data the paper's L2 index stores in its posting
    entries (prefix magnitudes ‖x'_j‖), reorganized for chunked evaluation:
    after the kernel has accumulated chunks 0..k, the unseen remainder of
    the dot product is bounded by ``out_q[i, k] * out_w[j, k]``.
    """
    n, d = x.shape
    n_chunks = d // chunk_d
    sq = (x.astype(jnp.float32) ** 2).reshape(n, n_chunks, chunk_d).sum(-1)
    # reverse-exclusive cumulative sum over chunks
    suffix_sq = jnp.flip(jnp.cumsum(jnp.flip(sq, axis=1), axis=1), axis=1)
    suffix_excl = jnp.concatenate(
        [suffix_sq[:, 1:], jnp.zeros((n, 1), jnp.float32)], axis=1
    )
    return jnp.sqrt(suffix_excl)


def _pad_rows(x: jax.Array, mult: int, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1), constant_values=fill)


@functools.partial(
    jax.jit,
    static_argnames=(
        "theta", "lam", "block_q", "block_w", "chunk_d", "interpret", "use_ref"
    ),
)
def sssj_join_tiles(
    q: jax.Array,
    w: jax.Array,
    tq: jax.Array,
    tw: jax.Array,
    uq: jax.Array,
    uw: jax.Array,
    *,
    theta: float,
    lam: float,
    block_q: int = 128,
    block_w: int = 128,
    chunk_d: int = 128,
    interpret: Optional[bool] = None,
    use_ref: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked time-decayed similarity join with per-tile telemetry.

    Args:
      q:  (Q, d) query vectors (unit-normalized; f32 or bf16).
      w:  (W, d) window vectors.
      tq: (Q,) or (Q, 1) query timestamps.
      tw: (W,) window timestamps.
      uq: (Q,) query uids (monotone stream counters).
      uw: (W,) window uids; negative marks empty ring slots.
      theta, lam: SSSJ parameters.
      use_ref: route through the pure-jnp oracle instead of the kernel.
        Inputs smaller than one block (Q < block_q, W < block_w, or
        d < chunk_d) are auto-routed through the reference as well — the
        kernel would spend its time on padding for them.

    Returns:
      scores: (Q, W) f32 — decayed similarity where ≥ θ (masked by uid
        order), 0 elsewhere.
      iters:  (nQ, nW) i32 — d-chunks executed per tile (pruning telemetry);
        all-`n_chunks` on the ref path.
      counts: (nQ, nW) i32 — emitted (≥ θ) entries per tile, stage 1 of the
        on-device pair compaction (see compact.py).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tq = tq.reshape(-1, 1).astype(jnp.float32)
    tw = tw.reshape(-1, 1).astype(jnp.float32)
    uq = uq.reshape(-1, 1).astype(jnp.int32)
    uw = uw.reshape(-1, 1).astype(jnp.int32)

    Q, d = q.shape
    W, _ = w.shape
    # ref fallback for unaligned tiny inputs: anything smaller than a single
    # kernel block would be all padding, so the dense jnp oracle is cheaper
    if Q < block_q or W < block_w or d < chunk_d:
        use_ref = True
    if use_ref:
        scores = sssj_join_ref(q, w, tq, tw, uq, uw, theta=theta, lam=lam)
        n_chunks = max(d // chunk_d, 1)
        iters = jnp.full(
            ((Q + block_q - 1) // block_q, (W + block_w - 1) // block_w),
            n_chunks,
            jnp.int32,
        )
        counts = tile_emit_counts(scores, block_q, block_w)
        return scores, iters, counts

    if d % chunk_d != 0:
        pad_d = (-d) % chunk_d
        q = jnp.pad(q, ((0, 0), (0, pad_d)))
        w = jnp.pad(w, ((0, 0), (0, pad_d)))
        d += pad_d

    qp = _pad_rows(q, block_q)
    wp = _pad_rows(w, block_w)
    tqp = _pad_rows(tq, block_q)
    twp = _pad_rows(tw, block_w)
    uqp = _pad_rows(uq, block_q, fill=NEG_UID)
    uwp = _pad_rows(uw, block_w, fill=NEG_UID)
    sqq = suffix_chunk_norms(qp, chunk_d)
    sqw = suffix_chunk_norms(wp, chunk_d)

    scores, iters, counts = sssj_join_kernel_call(
        qp, wp, tqp, twp, uqp, uwp, sqq, sqw,
        theta=theta, lam=lam,
        block_q=block_q, block_w=block_w, chunk_d=chunk_d,
        interpret=interpret,
    )
    return scores[:Q, :W], iters, counts


def sssj_join_scores(*args, **kw) -> tuple[jax.Array, jax.Array]:
    """Back-compat wrapper of :func:`sssj_join_tiles` without tile counts."""
    scores, iters, _ = sssj_join_tiles(*args, **kw)
    return scores, iters
