"""Hierarchical on-device pair compaction: tile candidates → packed pairs.

Level 2 of the two-level compaction pipeline (DESIGN.md §3).  Level 1 lives
with the join itself (the Pallas kernel, or its jnp mirrors in ops.py):
each (block_q, block_w) tile selects its own ≥θ entries into a fixed
``(tile_k,)`` candidate buffer plus a true-emit count — dead tiles (the
common case under time filtering) contribute a zero count and nothing
else.  This module merges those ragged per-segment buffers into the global
fixed-capacity :class:`PairBuffer` with a **segmented exclusive scan over
per-segment counts plus one gather** — there is no element-wise sort over
``Q·W`` anywhere, and the dense score matrix is never an input.

The same merge primitive is applied twice in the sharded engine: per-tile
buffers → per-shard buffer inside ``shard_map``, then per-shard buffers →
one global ``(max_pairs,)`` buffer after the gather, which is what makes
``max_pairs`` a *global* budget (DESIGN.md §5).

Drop accounting is per level and never silent:

  * ``PairCandidates.emitted - PairCandidates.kept`` — entries lost to the
    ``tile_k`` (or per-shard) candidate capacity;
  * ``PairBuffer.n_dropped`` — entries lost to the global ``max_pairs``
    budget at the merge;
  * ``PairBuffer.n_dropped_tile`` — upstream per-segment losses, carried so
    the lossless contract stays auditable end to end
    (``true pairs == n_pairs + n_dropped + n_dropped_tile``).

``compact_pairs`` (the PR-1 dense-matrix global-top-k compaction) is kept
verbatim as the test oracle for the ``emit_dense=True`` engine path.

Everything is shape-static and jit-safe, so join → select → merge → fetch
fuses into one XLA program and only ``O(max_pairs)`` bytes ever cross the
PCIe boundary.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PairBuffer",
    "PairCandidates",
    "compact_pairs",
    "concat_candidates",
    "merge_candidates",
    "tile_candidates",
    "tile_emit_counts",
]


class PairCandidates(NamedTuple):
    """Ragged per-segment candidate buffers (level-1 output, a pytree).

    A *segment* is a kernel tile (or, at the sharded engine's second merge
    level, one device's compacted buffer).  Each segment holds its first
    ``kept ≤ K`` emitted pairs in stream (row-major) order; slots past
    ``kept`` are inert (``uid = -1``, ``score = 0``).
    """

    uid_a: jax.Array    # (S, K) i32 — query-side uid, -1 in unused slots
    uid_b: jax.Array    # (S, K) i32 — window-side uid
    score: jax.Array    # (S, K) f32 — decayed similarity, 0 in unused slots
    kept: jax.Array     # (S,) i32 — valid entries per segment (≤ K)
    emitted: jax.Array  # (S,) i32 — true ≥θ count per segment (≥ kept)


class PairBuffer(NamedTuple):
    """Fixed-capacity compacted pair emission (a pytree of device arrays)."""

    uid_a: jax.Array     # (max_pairs,) i32 — query-side uid, -1 beyond n_pairs
    uid_b: jax.Array     # (max_pairs,) i32 — window-side uid, -1 beyond n_pairs
    score: jax.Array     # (max_pairs,) f32 — decayed similarity, 0 beyond n_pairs
    n_pairs: jax.Array   # () i32 — valid entries = min(total kept, max_pairs)
    n_dropped: jax.Array       # () i32 — entries lost to max_pairs (this merge)
    n_dropped_tile: jax.Array  # () i32 — entries lost upstream to per-segment
    #                                     (tile_k / per-shard) capacity

    @property
    def overflowed(self) -> jax.Array:
        return (self.n_dropped + self.n_dropped_tile) > 0


def _segmented_take(counts: jax.Array, seg_cap: int, out_cap: int):
    """Destination plan for packing ragged segments into a dense prefix.

    Given per-segment valid counts (each ≤ ``seg_cap``), returns
    ``(src, valid, total)`` where ``src[s]`` is the flat index (into the
    ``(S·seg_cap,)`` row-major segment buffer) of the s-th surviving entry,
    ``valid[s]`` marks ``s < min(total, out_cap)``, and ``total`` is the sum
    of counts.  Pure scan + binary search + gather — O(S + out_cap·log S),
    no sort, regardless of how many elements the segments describe.
    """
    counts = counts.astype(jnp.int32)
    n_seg = counts.shape[0]
    cum = jnp.cumsum(counts)                                   # inclusive
    total = cum[-1]
    s = jnp.arange(out_cap, dtype=jnp.int32)
    # segment holding global rank s = first seg whose inclusive cum > s
    seg = jnp.clip(
        jnp.searchsorted(cum, s, side="right"), 0, n_seg - 1
    ).astype(jnp.int32)
    base = cum[seg] - counts[seg]                              # exclusive scan
    valid = s < jnp.minimum(total, out_cap)
    src = seg * seg_cap + (s - base)
    return jnp.where(valid, src, 0), valid, total


def merge_candidates(cands: PairCandidates, *, max_pairs: int) -> PairBuffer:
    """Level-2 merge: ragged per-segment candidates → packed pair buffer.

    Survivors are the earliest pairs in (segment, within-segment) order;
    everything lost — here to ``max_pairs`` or upstream to per-segment
    capacity — is counted, never silent.
    """
    n_seg, seg_cap = cands.uid_a.shape
    kept = jnp.minimum(cands.kept.astype(jnp.int32), seg_cap)
    src, valid, total = _segmented_take(kept, seg_cap, max_pairs)
    uid_a = jnp.where(valid, cands.uid_a.reshape(-1)[src], -1).astype(jnp.int32)
    uid_b = jnp.where(valid, cands.uid_b.reshape(-1)[src], -1).astype(jnp.int32)
    score = jnp.where(valid, cands.score.reshape(-1)[src], 0.0).astype(jnp.float32)
    n_pairs = jnp.minimum(total, max_pairs).astype(jnp.int32)
    return PairBuffer(
        uid_a=uid_a,
        uid_b=uid_b,
        score=score,
        n_pairs=n_pairs,
        n_dropped=(total - n_pairs).astype(jnp.int32),
        n_dropped_tile=jnp.sum(cands.emitted - kept).astype(jnp.int32),
    )


def concat_candidates(*cands: PairCandidates) -> PairCandidates:
    """Stack candidate sets (e.g. window join + self join) along the
    segment axis; all must share the same per-segment capacity K."""
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *cands)


# --------------------------------------------------------------------- #
# jnp mirror of the kernel's level-1 tile selection (ref path + oracle)
# --------------------------------------------------------------------- #
def tile_candidates(
    scores: jax.Array,   # (Q, W) f32 — 0 where no pair, ≥ θ where emitted
    uq: jax.Array,       # (Q,) i32 query uids
    uw: jax.Array,       # (W,) i32 window uids aligned with score columns
    *,
    block_q: int,
    block_w: int,
    tile_k: int,
) -> tuple[PairCandidates, jax.Array]:
    """Per-(block_q, block_w)-tile candidate selection from a dense matrix.

    The jnp mirror of the kernel's level-1 stage, bit-compatible with it
    (same row-major within-tile order, same tile order), used by the dense
    ref path and as the oracle in tests.  Returns ``(candidates, row_mask)``
    with ``row_mask (Q,)`` = "row has ≥1 emitted entry" (exact even when
    ``tile_k`` overflows — it is derived from counts, not survivors).
    Scan + binary search + gather per tile; no sort.
    """
    Q, W = scores.shape
    pq, pw = (-Q) % block_q, (-W) % block_w
    s = jnp.pad(scores, ((0, pq), (0, pw)))
    uqp = jnp.pad(uq.astype(jnp.int32), (0, pq), constant_values=-1)
    uwp = jnp.pad(uw.astype(jnp.int32), (0, pw), constant_values=-1)
    nq, nw = (Q + pq) // block_q, (W + pw) // block_w
    n = block_q * block_w
    # (nq, nw, block_q, block_w) tiles, flattened row-major within the tile
    tiles = s.reshape(nq, block_q, nw, block_w).transpose(0, 2, 1, 3)
    flat = tiles.reshape(nq * nw, n)
    mask = flat > 0.0
    cum = jnp.cumsum(mask.astype(jnp.int32), axis=1)           # (S, n)
    emitted = cum[:, -1]
    kept = jnp.minimum(emitted, tile_k)
    target = jnp.arange(tile_k, dtype=jnp.int32) + 1
    # src[s, k] = first in-tile flat position with inclusive count ≥ k+1
    src = jax.vmap(lambda c: jnp.searchsorted(c, target, side="left"))(cum)
    src = jnp.minimum(src, n - 1).astype(jnp.int32)
    valid = target[None, :] <= kept[:, None]
    sel_score = jnp.where(valid, jnp.take_along_axis(flat, src, axis=1), 0.0)
    # in-tile (i, j) → global (qi, wi) → uids
    ti = jnp.arange(nq * nw, dtype=jnp.int32)[:, None] // nw
    tj = jnp.arange(nq * nw, dtype=jnp.int32)[:, None] % nw
    qi = ti * block_q + src // block_w
    wi = tj * block_w + src % block_w
    uid_a = jnp.where(valid, uqp[qi], -1)
    uid_b = jnp.where(valid, uwp[wi], -1)
    cands = PairCandidates(
        uid_a=uid_a, uid_b=uid_b, score=sel_score.astype(jnp.float32),
        kept=kept, emitted=emitted,
    )
    row_mask = jnp.any(
        (s > 0.0).reshape(nq * block_q, nw * block_w), axis=1
    )[:Q]
    return cands, row_mask


# --------------------------------------------------------------------- #
# PR-1 dense-matrix compaction — retained as the emit_dense test oracle
# --------------------------------------------------------------------- #
def compact_pairs(
    scores: jax.Array,   # (Q, W) f32 — 0 where no pair, ≥ θ where emitted
    uq: jax.Array,       # (Q,) i32 query uids
    uw: jax.Array,       # (W,) i32 window uids aligned with score columns
    *,
    max_pairs: int,
) -> PairBuffer:
    """Dense-oracle compaction: one stable ``lax.top_k`` over the whole
    emit mask (ties break toward the lower index, i.e. stream order).

    This is the path the hierarchical pipeline replaced — it materializes
    the dense matrix and sorts ``Q·W`` elements — kept only behind
    ``emit_dense=True`` so tests can assert the two paths agree
    pair-for-pair whenever no drop counter fires.
    """
    Q, W = scores.shape
    mask = scores > 0.0
    counts = jnp.sum(mask, axis=1, dtype=jnp.int32)            # (Q,)
    total = jnp.sum(counts)
    k = min(max_pairs, Q * W)
    hit, idx = jax.lax.top_k(mask.ravel().astype(jnp.float32), k)
    valid = hit > 0.0
    qi = (idx // W).astype(jnp.int32)
    wi = (idx % W).astype(jnp.int32)
    uid_a = jnp.where(valid, uq.astype(jnp.int32)[qi], -1)
    uid_b = jnp.where(valid, uw.astype(jnp.int32)[wi], -1)
    score = jnp.where(valid, scores.ravel().astype(jnp.float32)[idx], 0.0)
    if k < max_pairs:
        pad = max_pairs - k
        uid_a = jnp.concatenate([uid_a, jnp.full((pad,), -1, jnp.int32)])
        uid_b = jnp.concatenate([uid_b, jnp.full((pad,), -1, jnp.int32)])
        score = jnp.concatenate([score, jnp.zeros((pad,), jnp.float32)])
    n_pairs = jnp.minimum(total, max_pairs).astype(jnp.int32)
    return PairBuffer(
        uid_a, uid_b, score, n_pairs,
        (total - n_pairs).astype(jnp.int32), jnp.zeros((), jnp.int32),
    )


def tile_emit_counts(scores: jax.Array, block_q: int, block_w: int) -> jax.Array:
    """Per-(block_q, block_w)-tile emit counts from a dense score matrix —
    the jnp mirror of the kernel's stage-1 count output, for the ref path."""
    Q, W = scores.shape
    pq, pw = (-Q) % block_q, (-W) % block_w
    s = jnp.pad(scores, ((0, pq), (0, pw)))
    nq, nw = (Q + pq) // block_q, (W + pw) // block_w
    m = (s > 0.0).reshape(nq, block_q, nw, block_w)
    return jnp.sum(m, axis=(1, 3), dtype=jnp.int32)
