"""On-device pair compaction: dense thresholded scores → (uid_a, uid_b, s).

Stage 2 + 3 of the compaction pipeline (DESIGN.md §3).  The join kernel
emits a dense thresholded score matrix (zeros everywhere a pair was pruned
or below θ) plus per-tile emit counts (stage 1).  This module turns that
matrix into a fixed-capacity compacted buffer *without leaving the device*:

  stage 2 — **exclusive scan**: per-segment counts are scanned to produce
            each segment's base offset in the output buffer;
  stage 3 — **gather/scatter**: every emitted entry knows its destination
            ``base_offset + within-segment rank`` and is scattered into the
            ``(max_pairs,)`` buffers; entries past ``max_pairs`` are dropped
            and counted (the overflow contract).

Segments here are matrix rows (one query each): a row is the natural tile
at compaction granularity, and its count/scan/rank are pure VPU work.  The
kernel's per-(BQ, BW)-tile counts are the same quantity at MXU-tile
granularity and are used for telemetry and cross-checking (tests assert
``tile_counts.sum() == n_pairs + n_dropped``).

Everything is shape-static and jit-safe, so the whole join → compact →
fetch path fuses into one XLA program and only ``O(max_pairs)`` bytes —
not the dense ``(B, capacity)`` matrix — ever cross the PCIe boundary.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["PairBuffer", "compact_pairs", "tile_emit_counts"]


class PairBuffer(NamedTuple):
    """Fixed-capacity compacted pair emission (a pytree of device arrays)."""

    uid_a: jax.Array     # (max_pairs,) i32 — query-side uid, -1 beyond n_pairs
    uid_b: jax.Array     # (max_pairs,) i32 — window-side uid, -1 beyond n_pairs
    score: jax.Array     # (max_pairs,) f32 — decayed similarity, 0 beyond n_pairs
    n_pairs: jax.Array   # () i32 — valid entries = min(total emitted, max_pairs)
    n_dropped: jax.Array  # () i32 — entries lost to capacity (overflow flag > 0)

    @property
    def overflowed(self) -> jax.Array:
        return self.n_dropped > 0


def compact_pairs(
    scores: jax.Array,   # (Q, W) f32 — 0 where no pair, ≥ θ where emitted
    uq: jax.Array,       # (Q,) i32 query uids
    uw: jax.Array,       # (W,) i32 window uids aligned with score columns
    *,
    max_pairs: int,
) -> PairBuffer:
    """Count → scan-select → gather, entirely on device.

    The scan+select is expressed as a stable ``lax.top_k`` over the emit
    mask: ties break toward the lower index, so the returned indices are
    exactly the first ``max_pairs`` emitted positions in stream order —
    the same destinations an explicit exclusive-scan-of-counts would
    assign, but as one fused gather instead of a large scatter (XLA CPU
    serializes scatters; top_k + gather also maps better onto the TPU's
    sort unit).
    """
    Q, W = scores.shape
    mask = scores > 0.0
    # stage 1: per-segment counts (the kernel already produced these per
    # MXU tile — recomputed at row granularity, still device-resident)
    counts = jnp.sum(mask, axis=1, dtype=jnp.int32)            # (Q,)
    total = jnp.sum(counts)
    # stage 2+3: select the first max_pairs emitted positions and gather
    k = min(max_pairs, Q * W)
    hit, idx = jax.lax.top_k(mask.ravel().astype(jnp.float32), k)
    valid = hit > 0.0
    qi = (idx // W).astype(jnp.int32)
    wi = (idx % W).astype(jnp.int32)
    uid_a = jnp.where(valid, uq.astype(jnp.int32)[qi], -1)
    uid_b = jnp.where(valid, uw.astype(jnp.int32)[wi], -1)
    score = jnp.where(valid, scores.ravel().astype(jnp.float32)[idx], 0.0)
    if k < max_pairs:
        pad = max_pairs - k
        uid_a = jnp.concatenate([uid_a, jnp.full((pad,), -1, jnp.int32)])
        uid_b = jnp.concatenate([uid_b, jnp.full((pad,), -1, jnp.int32)])
        score = jnp.concatenate([score, jnp.zeros((pad,), jnp.float32)])
    n_pairs = jnp.minimum(total, max_pairs).astype(jnp.int32)
    return PairBuffer(uid_a, uid_b, score, n_pairs, (total - n_pairs).astype(jnp.int32))


def tile_emit_counts(scores: jax.Array, block_q: int, block_w: int) -> jax.Array:
    """Per-(block_q, block_w)-tile emit counts from a dense score matrix —
    the jnp mirror of the kernel's stage-1 output, for the ref path."""
    Q, W = scores.shape
    pq, pw = (-Q) % block_q, (-W) % block_w
    s = jnp.pad(scores, ((0, pq), (0, pw)))
    nq, nw = (Q + pq) // block_q, (W + pw) // block_w
    m = (s > 0.0).reshape(nq, block_q, nw, block_w)
    return jnp.sum(m, axis=(1, 3), dtype=jnp.int32)
