from .compact import (  # noqa: F401
    PairBuffer,
    PairCandidates,
    compact_pairs,
    concat_candidates,
    merge_candidates,
    tile_candidates,
    tile_emit_counts,
)
from .gate import (  # noqa: F401
    StripSummary,
    init_strip_summary,
    refresh_strip_summary,
    strip_gate,
    summarize_strips,
)
from .ops import (  # noqa: F401
    JoinCandidates,
    NEG_UID,
    sssj_join_candidates,
    sssj_join_scores,
    sssj_join_tiles,
    suffix_chunk_norms,
)
from .ref import sssj_join_ref  # noqa: F401
