from .ops import sssj_join_scores, suffix_chunk_norms, NEG_UID  # noqa: F401
from .ref import sssj_join_ref  # noqa: F401
