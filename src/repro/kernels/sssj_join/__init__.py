from .compact import PairBuffer, compact_pairs, tile_emit_counts  # noqa: F401
from .ops import sssj_join_scores, sssj_join_tiles, suffix_chunk_norms, NEG_UID  # noqa: F401
from .ref import sssj_join_ref  # noqa: F401
