"""Pure-jnp oracle for the blocked time-decayed join kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["sssj_join_ref"]


def sssj_join_ref(q, w, tq, tw, uq, uw, *, theta: float, lam: float):
    """Dense reference: thresholded decayed scores with uid-order masking.

    Args mirror the kernel: ``q (Q, d)``, ``w (W, d)``, timestamps ``(·, 1)``
    float, uids ``(·, 1)`` int (negative = empty slot).  Returns the
    ``(Q, W)`` float32 score matrix: ``dot·exp(-λΔt)`` where that value is
    ≥ θ and ``uid_q > uid_w ≥ 0``, else 0.
    """
    qf = q.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    sims = qf @ wf.T
    dt = jnp.abs(tq.astype(jnp.float32) - tw.astype(jnp.float32).T)
    dec = sims * jnp.exp(-lam * dt)
    order = (uw.T >= 0) & (uq > uw.T)
    dec = jnp.where(order, dec, 0.0)
    return jnp.where(dec >= theta, dec, 0.0).astype(jnp.float32)
