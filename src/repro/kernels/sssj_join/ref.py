"""Pure-jnp oracle for the blocked time-decayed join kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["sssj_join_ref"]


def sssj_join_ref(
    q, w, tq, tw, uq, uw, *, theta: float, lam: float,
    sq: Optional[jax.Array] = None,
    sw: Optional[jax.Array] = None,
    theta_q: Optional[jax.Array] = None,
    lam_q: Optional[jax.Array] = None,
):
    """Dense reference: thresholded decayed scores with uid-order masking.

    Args mirror the kernel: ``q (Q, d)``, ``w (W, d)``, timestamps ``(·, 1)``
    float, uids ``(·, 1)`` int (negative = empty slot).  Returns the
    ``(Q, W)`` float32 score matrix: ``dot·exp(-λΔt)`` where that value is
    ≥ θ and ``uid_q > uid_w ≥ 0``, else 0.

    Multi-tenant lanes (DESIGN.md §9, all optional):

      * ``sq (Q, 1)`` / ``sw (W, 1)`` — stream ids; a stream-equality mask
        is folded into the order mask so cross-stream pairs never emit;
      * ``theta_q (Q, 1)`` / ``lam_q (Q, 1)`` — per-row (θ, λ) looked up
        from the tenant table.  A pair's stream is its query row's stream
        (the equality mask guarantees it), so query-side values govern the
        whole pair.
    """
    qf = q.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    sims = qf @ wf.T
    dt = jnp.abs(tq.astype(jnp.float32) - tw.astype(jnp.float32).T)
    lam_eff = lam if lam_q is None else lam_q.astype(jnp.float32)
    dec = sims * jnp.exp(-lam_eff * dt)
    order = (uw.T >= 0) & (uq > uw.T)
    if sq is not None:
        order &= sq.astype(jnp.int32) == sw.astype(jnp.int32).T
    dec = jnp.where(order, dec, 0.0)
    thr = theta if theta_q is None else theta_q.astype(jnp.float32)
    return jnp.where(dec >= thr, dec, 0.0).astype(jnp.float32)
