"""Device-resident strip summaries: the L2/prefix candidate-generation gate.

The paper's L2 index wins by never *scoring* most candidates: prefix
filtering and ℓ2-norm bounds kill a candidate before its dot product is
computed.  The device engine so far only pruned *inside* a launched tile
(the kernel's tile-level time filter + chunked suffix bound); every
(query-tile × window-strip) program still launched.  This module lifts the
paper's index-side bounds to strip granularity so dead tiles are never
launched at all:

  * :class:`StripSummary` — per-strip aggregates carried in the engine's
    ``lax.scan`` state alongside the ring buffer: top-weight coordinate
    prefixes (``vmax``: per-dimension max |w| over the strip, the paper's
    max-vector m̂ restricted to a strip), per-chunk max row norms
    (``cnorm``: the ℓ2/suffix-bound aggregate at chunk granularity), and
    the strip's live time extremes + max uid (the time-filter aggregate).
  * :func:`summarize_strips` / :func:`refresh_strip_summary` — full and
    incremental maintenance.  The refresh is what the write-slot policy
    layer calls after every ring write: it recomputes exactly the strips
    the write touched (a gather of ``block_w`` slots per written row —
    capacity-independent), under any eviction policy, because it keys off
    the *destination slots*, not off any policy-specific structure.
  * :func:`strip_gate` — the admissible pre-launch gate: for each
    (query-tile, strip) it bounds every pair's decayed score by
    ``min(prefix_bound, l2_bound) · exp(-λ_min · Δt_min)`` and compares
    against the unpadded per-batch min-θ (the same scalars the tenant-table
    pruning uses, DESIGN.md §10) — so a gated-off tile provably cannot emit
    for *any* row, under per-row (θ, λ) and on every shard.

Admissibility (DESIGN.md §13): for a query row x and a window row y in
strip s,

    dot(x, y) ≤ Σ_i |x_i| · vmax_s[i]                 (prefix bound)
    dot(x, y) ≤ Σ_c ‖x_c‖ · cnorm_s[c]                (chunked ℓ2 bound)
    |Δt|      ≥ max(0, tq_lo − tmax_s, tmin_s − tq_hi) = Δt_min

with λ_row ≥ λ_min and θ_row ≥ θ_min over the *unpadded* batch, so

    score = dot · exp(-λ_row |Δt|) ≤ ub · exp(-λ_min Δt_min) < θ_min ≤ θ_row

whenever the gate says dead.  Both value bounds hold with absolute values
(the bounds are ≥ 0 while emission needs score ≥ θ > 0), and the chunked
ℓ2 bound is itself ≤ ‖x‖·‖y‖ by Cauchy–Schwarz on the chunk-norm vectors
— never looser than the whole-vector bound the host index implies.

Empty / padded slots are inert by construction: ``vmax = cnorm = 0``,
``umax = -1``, ``tmin = +3e30``, ``tmax = -3e30`` — an empty strip is
gated off by both the uid check and the time bound.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "StripSummary",
    "init_strip_summary",
    "refresh_strip_summary",
    "strip_gate",
    "summarize_strips",
]

_EMPTY_TS = jnp.float32(3.0e30)


class StripSummary(NamedTuple):
    """Per-strip index aggregates (a pytree; one row per window strip).

    Shapes for a window of ``capacity`` slots summarized at ``block_w``
    granularity with ``n_strips = ceil(capacity / block_w)`` and
    ``n_chunks = ceil(d / chunk_d)``:
    """

    vmax: jax.Array   # (n_strips, d) f32 — per-dim max |w| over live slots
    cnorm: jax.Array  # (n_strips, n_chunks) f32 — per-chunk max row norm
    tmin: jax.Array   # (n_strips,) f32 — min live ts (+3e30 when empty)
    tmax: jax.Array   # (n_strips,) f32 — max live ts (-3e30 when empty)
    umax: jax.Array   # (n_strips,) i32 — max uid (-1 when empty)


def init_strip_summary(
    capacity: int, d: int, *, block_w: int, chunk_d: int
) -> StripSummary:
    """Summary of an all-empty window (matches ``summarize_strips`` on
    a fresh :func:`~repro.engine.window.init_window` state)."""
    ns = -(-capacity // block_w)
    nc = -(-d // chunk_d)
    return StripSummary(
        vmax=jnp.zeros((ns, d), jnp.float32),
        cnorm=jnp.zeros((ns, nc), jnp.float32),
        tmin=jnp.full((ns,), _EMPTY_TS, jnp.float32),
        tmax=jnp.full((ns,), -_EMPTY_TS, jnp.float32),
        umax=jnp.full((ns,), -1, jnp.int32),
    )


def _strip_stats(v, t, u, chunk_d: int):
    """Shared reduction: ``(g, block_w, ·)`` slot groups → per-group
    aggregates.  ``v`` must already be zero-padded to a chunk multiple."""
    g, bw, dp = v.shape
    nc = dp // chunk_d
    live = u >= 0                                        # (g, bw)
    lv = live[:, :, None].astype(jnp.float32)
    vmax = jnp.max(jnp.abs(v) * lv, axis=1)              # (g, dp)
    cn = jnp.sqrt((v * v).reshape(g, bw, nc, chunk_d).sum(-1))
    cnorm = jnp.max(cn * lv, axis=1)                     # (g, nc)
    tmin = jnp.min(jnp.where(live, t, _EMPTY_TS), axis=1)
    tmax = jnp.max(jnp.where(live, t, -_EMPTY_TS), axis=1)
    umax = jnp.max(u, axis=1)
    return vmax, cnorm, tmin, tmax, umax


def summarize_strips(
    vecs: jax.Array, ts: jax.Array, uids: jax.Array,
    *, block_w: int, chunk_d: int,
) -> StripSummary:
    """Full (re)build: summarize every strip of a window from scratch.

    Ragged tails are handled on both axes: a capacity that is not a
    ``block_w`` multiple pads the last strip with inert empty slots, and a
    feature dim that is not a ``chunk_d`` multiple pads with zeros —
    exactly the padding the join applies, so the bounds line up with what
    the kernel actually computes.
    """
    cap, d = vecs.shape
    ns = -(-cap // block_w)
    nc = -(-d // chunk_d)
    pad_r = ns * block_w - cap
    pad_c = nc * chunk_d - d
    v = jnp.pad(vecs.astype(jnp.float32), ((0, pad_r), (0, pad_c)))
    t = jnp.pad(ts.astype(jnp.float32), (0, pad_r), constant_values=_EMPTY_TS)
    u = jnp.pad(uids.astype(jnp.int32), (0, pad_r), constant_values=-1)
    vmax, cnorm, tmin, tmax, umax = _strip_stats(
        v.reshape(ns, block_w, nc * chunk_d),
        t.reshape(ns, block_w),
        u.reshape(ns, block_w),
        chunk_d,
    )
    return StripSummary(
        vmax=vmax[:, :d], cnorm=cnorm, tmin=tmin, tmax=tmax, umax=umax
    )


def refresh_strip_summary(
    summary: StripSummary,
    vecs: jax.Array, ts: jax.Array, uids: jax.Array,
    dest: jax.Array,
    *, block_w: int, chunk_d: int,
) -> StripSummary:
    """Incremental maintenance: recompute the strips a write touched.

    ``vecs/ts/uids`` are the **post-write** window arrays and ``dest (b,)``
    the slots the write-slot policy selected (``capacity`` is the drop
    sentinel, see :func:`~repro.engine.window.select_write_slots`), so this
    works identically under all eviction policies — including ``"quota"``,
    where the victim strip is the writer's own sub-ring.  Cost is
    ``O(b · block_w · d)`` per micro-batch, independent of capacity.

    Rows writing into the same strip recompute identical aggregates, so
    the duplicate scatter indices below are value-deterministic; sentinel
    rows map to strip id ``n_strips`` and are dropped by the scatter mode.
    """
    cap, d = vecs.shape
    ns = summary.umax.shape[0]
    nc = summary.cnorm.shape[1]
    pad_c = nc * chunk_d - d
    dest = dest.astype(jnp.int32)
    # NOT a bare dest // block_w: the drop sentinel (dest == cap) would
    # collide with the last real strip whenever cap % block_w != 0
    sid = jnp.where(dest < cap, dest // block_w, ns)
    base = jnp.clip(sid, 0, ns - 1) * block_w
    idx = base[:, None] + jnp.arange(block_w, dtype=jnp.int32)[None, :]
    ok = idx < cap                                       # ragged last strip
    idx_c = jnp.minimum(idx, cap - 1)
    v = vecs[idx_c].astype(jnp.float32) * ok[:, :, None]
    t = jnp.where(ok, ts[idx_c].astype(jnp.float32), _EMPTY_TS)
    u = jnp.where(ok, uids[idx_c].astype(jnp.int32), -1)
    if pad_c:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_c)))
    vmax, cnorm, tmin, tmax, umax = _strip_stats(v, t, u, chunk_d)
    return StripSummary(
        vmax=summary.vmax.at[sid].set(vmax[:, :d], mode="drop"),
        cnorm=summary.cnorm.at[sid].set(cnorm, mode="drop"),
        tmin=summary.tmin.at[sid].set(tmin, mode="drop"),
        tmax=summary.tmax.at[sid].set(tmax, mode="drop"),
        umax=summary.umax.at[sid].set(umax, mode="drop"),
    )


# --------------------------------------------------------------------- #
# the pre-launch gate
# --------------------------------------------------------------------- #
def _gate_ub_kernel(qa_ref, qcn_ref, vmax_ref, cnorm_ref, ub_ref):
    """One query tile vs every strip: ``ub[j] = max_i min(pb, lb)[i, j]``."""
    f32 = jnp.float32
    pb = jax.lax.dot_general(
        qa_ref[...], vmax_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=f32,
    )
    lb = jax.lax.dot_general(
        qcn_ref[...], cnorm_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=f32,
    )
    ub_ref[...] = jnp.max(jnp.minimum(pb, lb), axis=0, keepdims=True)


def _tile_ub_pallas(qa, qcn, vmax, cnorm, *, block_q: int, interpret: bool):
    Qp, d = qa.shape
    ns, nc = cnorm.shape
    nq = Qp // block_q
    return pl.pallas_call(
        _gate_ub_kernel,
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q, nc), lambda i: (i, 0)),
            pl.BlockSpec((ns, d), lambda i: (0, 0)),
            pl.BlockSpec((ns, nc), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ns), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, ns), jnp.float32),
        interpret=interpret,
    )(qa, qcn, vmax, cnorm)


def _chunk_norms(x: jax.Array, chunk_d: int) -> jax.Array:
    """``out[i, c] = ‖x_i restricted to chunk c‖`` (f32, (n, n_chunks))."""
    n, d = x.shape
    nc = d // chunk_d
    sq = (x.astype(jnp.float32) ** 2).reshape(n, nc, chunk_d).sum(-1)
    return jnp.sqrt(sq)


@functools.partial(
    jax.jit, static_argnames=("block_q", "chunk_d", "impl", "interpret")
)
def strip_gate(
    qp: jax.Array,
    summary: StripSummary,
    *,
    block_q: int,
    chunk_d: int,
    tq_lo: jax.Array,
    tq_hi: jax.Array,
    th_min,
    lam_min,
    impl: str = "jnp",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Admissible per-(query-tile × strip) launch gate.

    Args:
      qp: (Qp, d_pad) padded query block — ``d_pad`` a ``chunk_d`` multiple
        (padded rows carry zero vectors, which only loosen the tile max).
      summary: strip aggregates for the window being joined; ``vmax`` may
        be narrower than ``d_pad`` (the join zero-pads features) and is
        zero-padded here to match.
      tq_lo/tq_hi, th_min/lam_min: extremes over the **unpadded** batch
        (padding fills would loosen / corrupt the bounds, ops.py contract).
      impl: ``"jnp"`` or ``"pallas"`` for the value-bound matmuls (the
        Pallas variant keeps the (Qp, n_strips) bound matrices in VMEM,
        worth it when the join itself runs as the Pallas kernel).

    Returns:
      gate:  (nq, n_strips) bool — True where the tile must launch.
      stats: (3,) i32 — ``[tiles_skipped_time, tiles_skipped_l2,
        strips_survived]`` (tiles_total is ``gate.size``, already counted
        by the engine's ``tiles`` telemetry).
    """
    Qp, d_pad = qp.shape
    nq = Qp // block_q
    ns, d_s = summary.vmax.shape
    vmax = summary.vmax
    if d_s < d_pad:
        vmax = jnp.pad(vmax, ((0, 0), (0, d_pad - d_s)))
    qa = jnp.abs(qp.astype(jnp.float32))
    qcn = _chunk_norms(qp, chunk_d)
    if impl == "pallas":
        ub_tile = _tile_ub_pallas(
            qa, qcn, vmax, summary.cnorm, block_q=block_q, interpret=interpret
        )
    else:
        pb = qa @ vmax.T                                  # (Qp, ns)
        lb = qcn @ summary.cnorm.T                        # (Qp, ns)
        ub_tile = jnp.max(
            jnp.minimum(pb, lb).reshape(nq, block_q, ns), axis=1
        )
    dt_lb = jnp.maximum(
        0.0, jnp.maximum(tq_lo - summary.tmax, summary.tmin - tq_hi)
    )
    decay_ub = jnp.exp(-lam_min * dt_lb)                  # (ns,)
    time_alive = (decay_ub >= th_min) & (summary.umax >= 0)
    gate = time_alive[None, :] & (ub_tile * decay_ub[None, :] >= th_min)
    skipped_time = nq * jnp.sum(jnp.logical_not(time_alive).astype(jnp.int32))
    skipped_l2 = jnp.sum(
        (time_alive[None, :] & jnp.logical_not(gate)).astype(jnp.int32)
    )
    survived = jnp.sum(jnp.any(gate, axis=0).astype(jnp.int32))
    stats = jnp.stack([skipped_time, skipped_l2, survived]).astype(jnp.int32)
    return gate, stats
