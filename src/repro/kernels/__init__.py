"""Pallas TPU kernels for the performance-critical compute of the system.

Each kernel lives in its own subpackage with the standard layout:

  * ``kernel.py`` — ``pl.pallas_call`` body + explicit BlockSpec VMEM tiling
  * ``ops.py``    — jit'd public wrapper (padding, bound precomputation)
  * ``ref.py``    — pure-jnp oracle used by the allclose test sweeps

Kernels target TPU; on this CPU-only container they are validated in
``interpret=True`` mode (the wrappers auto-detect the backend).
"""

from .sssj_join.ops import sssj_join_scores  # noqa: F401
from .flash_attention.ops import flash_attention  # noqa: F401
