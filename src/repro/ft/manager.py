"""Checkpoint manager: retention policy + async (off-thread) saves.

The device→host gather happens synchronously (so the saved state is the
state at the save point, not a torn snapshot); only the disk IO runs on the
background thread — the same split a real multi-host async checkpointer
makes.
"""

from __future__ import annotations

import pathlib
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from .checkpoint import list_checkpoints, restore_checkpoint, save_checkpoint

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        async_save: bool = True,
    ) -> None:
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, state: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot to host, then write (async if configured)."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_state, extra)
                self._retain()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # ------------------------------------------------------------------ #
    def latest_path(self) -> Optional[pathlib.Path]:
        cps = list_checkpoints(self.directory)
        return cps[-1] if cps else None

    def restore_latest(self, like: Any, shardings: Optional[Any] = None):
        """Returns (state, extra, step) or None if no checkpoint exists."""
        self.wait()
        path = self.latest_path()
        if path is None:
            return None
        return restore_checkpoint(path, like, shardings)

    def _retain(self) -> None:
        cps = list_checkpoints(self.directory)
        for p in cps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(p, ignore_errors=True)
