"""Atomic, resharding checkpoints with an index manifest.

Layout of one checkpoint::

    <dir>/step_000123/
        MANIFEST.json       # tree structure, per-leaf file/shape/dtype, meta
        leaf_00000.npy ...  # one .npy per pytree leaf (host-gathered)

Properties needed at 1000-node scale, scaled to this container:

  * **atomic** — written to ``step_X.tmp`` and ``os.replace``d into place;
    a crash mid-save never corrupts the latest checkpoint;
  * **reshard-on-load** — leaves are restored with ``jax.device_put`` against
    *whatever shardings the new mesh wants*: restoring a 2-pod checkpoint
    onto 1 pod (elastic shrink) or onto more pods (grow) is the same call;
  * **self-describing** — MANIFEST carries the flattened key paths, so a
    checkpoint can be inspected / partially loaded without the model code;
  * **data-pipeline state included** — exact-resume without sample loss.

(A production deployment would use a parallel-IO array store; the format
here keeps the *semantics* — atomicity, manifest, resharding — with plain
numpy files.)
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "list_checkpoints"]

_MANIFEST = "MANIFEST.json"


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    state: Any,
    extra: Optional[Dict[str, Any]] = None,
) -> pathlib.Path:
    """Write ``state`` (any pytree) atomically.  Returns the final path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = pathlib.Path(
        tempfile.mkdtemp(prefix=f".step_{step:08d}.tmp", dir=directory)
    )
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    index = []
    for i, (path, leaf) in enumerate(leaves):
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / fname, arr, allow_pickle=False)
        index.append(
            {
                "key": _keystr(path),
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        )
    manifest = {"step": step, "leaves": index, "extra": extra or {}}
    with open(tmp / _MANIFEST, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def list_checkpoints(directory: str | os.PathLike):
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    out = []
    for p in sorted(directory.iterdir()):
        if p.is_dir() and p.name.startswith("step_") and (p / _MANIFEST).exists():
            out.append(p)
    return out


def restore_checkpoint(
    path: str | os.PathLike,
    like: Any,
    shardings: Optional[Any] = None,
):
    """Restore into the structure of ``like``; reshard to ``shardings``.

    ``like`` supplies the pytree structure (arrays or ShapeDtypeStructs).
    ``shardings`` — optional matching pytree of ``jax.sharding.Sharding`` —
    places each leaf directly onto the (possibly different) mesh.
    Returns ``(state, extra, step)``.
    """
    path = pathlib.Path(path)
    with open(path / _MANIFEST) as f:
        manifest = json.load(f)
    paths_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    if len(manifest["leaves"]) != len(paths_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target structure has {len(paths_like)}"
        )
    by_key = {e["key"]: e for e in manifest["leaves"]}
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
        )[0]
    out = []
    for i, (kpath, leaf) in enumerate(paths_like):
        key = _keystr(kpath)
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(path / entry["file"], allow_pickle=False)
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {want_shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest.get("extra", {}), manifest["step"]
