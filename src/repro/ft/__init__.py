"""Fault tolerance: checkpointing, health tracking, elastic re-meshing."""

from .checkpoint import restore_checkpoint, save_checkpoint  # noqa: F401
from .manager import CheckpointManager  # noqa: F401
from .health import ElasticPlanner, HeartbeatTracker  # noqa: F401
