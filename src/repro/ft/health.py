"""Cluster health: heartbeats, straggler detection, elastic re-mesh plans.

At 1000+ nodes the failure model is: hosts die (no heartbeat), hosts
straggle (heartbeats arrive but step progress lags), and capacity changes
(nodes added back after repair).  The tracker is pure logic over
(worker, step, time) triples so it is unit-testable without a cluster;
the training loop feeds it and acts on its verdicts:

  * ``dead()``      → trigger checkpoint-restore on a re-planned mesh
  * ``stragglers()``→ exclude from the next re-plan (p99-lag rule)
  * ``ElasticPlanner.plan()`` → largest viable (pod, data, model) mesh from
    the surviving host set; restore reshards onto it (ft/checkpoint.py)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["HeartbeatTracker", "ElasticPlanner", "MeshPlan"]


@dataclasses.dataclass
class _Beat:
    step: int
    t: float


class HeartbeatTracker:
    """Tracks (worker → latest step/time); classifies dead and stragglers."""

    def __init__(self, dead_after_s: float = 60.0, lag_factor: float = 3.0):
        self.dead_after_s = dead_after_s
        self.lag_factor = lag_factor
        self._beats: Dict[str, _Beat] = {}

    def record(self, worker: str, step: int, t: float) -> None:
        b = self._beats.get(worker)
        if b is None or step >= b.step:
            self._beats[worker] = _Beat(step, t)

    def workers(self) -> List[str]:
        return sorted(self._beats)

    def dead(self, now: float) -> List[str]:
        return sorted(
            w for w, b in self._beats.items() if now - b.t > self.dead_after_s
        )

    def stragglers(self, now: float) -> List[str]:
        """Workers alive but lagging the fleet's step progress.

        Rule: a worker is a straggler if its step lag behind the p50 step
        exceeds ``lag_factor ×`` the p50→p99 spread (robust to the fleet
        being globally slow), with a floor of 2 steps.
        """
        alive = {
            w: b for w, b in self._beats.items()
            if now - b.t <= self.dead_after_s
        }
        if len(alive) < 4:
            return []
        steps = np.array([b.step for b in alive.values()], dtype=np.float64)
        p50 = np.percentile(steps, 50)
        p99 = np.percentile(steps, 99)
        spread = max(p99 - p50, 1.0)
        thresh = max(self.lag_factor * spread, 2.0)
        return sorted(w for w, b in alive.items() if (p50 - b.step) > thresh)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    hosts_used: int
    hosts_dropped: int


class ElasticPlanner:
    """Choose the largest viable production mesh for a surviving host set.

    The production topology is pods of 64 hosts (256 chips at 4 chips/host,
    mesh tile (data=16, model=16)).  Elastic policy: keep the model axis at
    16 (TP must match the compiled program's expectations), scale the data
    and pod axes down/up to the largest whole tile count.
    """

    def __init__(self, chips_per_host: int = 4, model_axis: int = 16,
                 data_axis: int = 16):
        self.chips_per_host = chips_per_host
        self.model_axis = model_axis
        self.data_axis = data_axis
        self.chips_per_pod = model_axis * data_axis

    def plan(self, alive_hosts: int) -> Optional[MeshPlan]:
        chips = alive_hosts * self.chips_per_host
        pods = chips // self.chips_per_pod
        if pods < 1:
            # degrade: shrink the data axis while keeping model=16
            for data in (8, 4, 2, 1):
                need = self.model_axis * data
                if chips >= need:
                    used = need // self.chips_per_host
                    return MeshPlan(
                        (data, self.model_axis), ("data", "model"),
                        hosts_used=used, hosts_dropped=alive_hosts - used,
                    )
            return None
        if pods == 1:
            used = self.chips_per_pod // self.chips_per_host
            return MeshPlan(
                (self.data_axis, self.model_axis), ("data", "model"),
                hosts_used=used, hosts_dropped=alive_hosts - used,
            )
        used = pods * self.chips_per_pod // self.chips_per_host
        return MeshPlan(
            (pods, self.data_axis, self.model_axis), ("pod", "data", "model"),
            hosts_used=used, hosts_dropped=alive_hosts - used,
        )
