"""Request router: admission queue + coalescer for multi-tenant streams.

The device engine wants full ``micro_batch``-row scans; a single low-rate
tenant never fills one.  The router admits sub-batch arrivals from many
tenants into one global FIFO (strict admission order — this is what makes
results invariant to coalescing boundaries, DESIGN.md §9) and hands the
runtime exact row counts back out when it packs micro-batches.

Responsibilities kept deliberately narrow:

  * **admission order is the only order** — items leave exactly as they
    arrived, across all tenants, so the device sees one deterministic
    interleaved stream regardless of how callers batched their submits or
    when flushes happen;
  * **backpressure** — a per-tenant cap on queued rows; an over-cap submit
    raises :class:`TenantBackpressure` *before* anything is enqueued (all
    or nothing), so a runaway tenant cannot starve the others of queue
    memory;
  * **telemetry** — queued depth per tenant, admitted/rejected counts, and
    queue-delay (admission → take) sums/maxima for the operator.

The router never touches the payload beyond concatenation: vectors and
token batches coalesce identically.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Tuple

import numpy as np

__all__ = ["RequestRouter", "RouterTelemetry", "TenantBackpressure"]


class TenantBackpressure(RuntimeError):
    """A tenant's queued rows would exceed its backpressure cap."""

    def __init__(self, tenant: int, queued: int, incoming: int, cap: int):
        super().__init__(
            f"stream {tenant}: {queued} rows queued + {incoming} incoming "
            f"exceeds the backpressure cap ({cap}); drain with flush() or "
            f"raise max_queue_per_tenant"
        )
        self.tenant = tenant


@dataclasses.dataclass
class RouterTelemetry:
    items_admitted: int = 0
    items_rejected: int = 0     # rows refused by backpressure (submit raised)
    items_dispatched: int = 0   # rows handed to the device packer
    queue_delay_sum_s: float = 0.0  # admission → take, summed over rows
    queue_delay_max_s: float = 0.0


@dataclasses.dataclass
class _Chunk:
    tenant: int
    payload: np.ndarray      # (b, ...) vectors or token rows
    ts: np.ndarray           # (b,) f64
    uids: np.ndarray         # (b,) i32 — global, assigned at admission
    t_admit: float           # wall clock, for queue-delay telemetry
    start: int = 0           # rows [0, start) already taken


class RequestRouter:
    """Order-preserving admission queue with per-tenant backpressure."""

    def __init__(self, n_tenants: int, max_queue_per_tenant: int = 65536):
        if max_queue_per_tenant < 1:
            raise ValueError("max_queue_per_tenant must be ≥ 1")
        self.n_tenants = n_tenants
        self.max_queue_per_tenant = max_queue_per_tenant
        self._queue: Deque[_Chunk] = deque()
        self._queued_rows = 0
        self.queued_by_tenant: Dict[int, int] = {t: 0 for t in range(n_tenants)}
        self.telemetry = RouterTelemetry()

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        """Rows currently queued (all tenants)."""
        return self._queued_rows

    def admit(
        self,
        tenant: int,
        payload: np.ndarray,
        ts: np.ndarray,
        uids: np.ndarray,
    ) -> None:
        b = payload.shape[0]
        queued = self.queued_by_tenant[tenant]
        if queued + b > self.max_queue_per_tenant:
            self.telemetry.items_rejected += b
            raise TenantBackpressure(tenant, queued, b, self.max_queue_per_tenant)
        self._queue.append(
            _Chunk(tenant, payload, ts, uids, t_admit=time.monotonic())
        )
        self.queued_by_tenant[tenant] = queued + b
        self._queued_rows += b
        self.telemetry.items_admitted += b

    def take(
        self, n: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Pop exactly ``n`` rows (``n ≤ len(self)``) in admission order.

        Returns ``(payload (n, ...), ts (n,), uids (n,), sids (n,),
        t_admit (n,))`` — ``t_admit`` is each row's monotonic admission
        stamp, the anchor for admission→emission latency attribution
        (DESIGN.md §12).  A partially-consumed head chunk stays queued
        with its cursor advanced, so micro-batch boundaries never reorder
        or drop rows.
        """
        if n > self._queued_rows:
            raise ValueError(f"take({n}) exceeds {self._queued_rows} queued rows")
        now = time.monotonic()
        tel = self.telemetry
        parts: List[Tuple[_Chunk, int, int]] = []   # (chunk, lo, hi)
        got = 0
        while got < n:
            c = self._queue[0]
            avail = c.payload.shape[0] - c.start
            k = min(avail, n - got)
            parts.append((c, c.start, c.start + k))
            delay = max(0.0, now - c.t_admit)
            tel.queue_delay_sum_s += delay * k
            tel.queue_delay_max_s = max(tel.queue_delay_max_s, delay)
            self.queued_by_tenant[c.tenant] -= k
            got += k
            if k == avail:
                self._queue.popleft()
            else:
                c.start += k
        self._queued_rows -= n
        tel.items_dispatched += n
        payload = np.concatenate([c.payload[lo:hi] for c, lo, hi in parts])
        ts = np.concatenate([c.ts[lo:hi] for c, lo, hi in parts])
        uids = np.concatenate([c.uids[lo:hi] for c, lo, hi in parts])
        sids = np.concatenate(
            [np.full(hi - lo, c.tenant, np.int32) for c, lo, hi in parts]
        )
        t_admit = np.concatenate(
            [np.full(hi - lo, c.t_admit, np.float64) for c, lo, hi in parts]
        )
        return payload, ts, uids, sids, t_admit
