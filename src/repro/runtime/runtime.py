"""Multi-tenant streaming runtime: K logical streams on one engine.

The engine (DESIGN.md §4) assumes one logical stream whose arrival rate
fills 128-row micro-batches.  The ROADMAP's serving target is the
opposite shape: thousands of small independent streams, each too slow to
fill a micro-batch alone.  This runtime multiplexes them (DESIGN.md §9),
onto either the single-device engine or the sharded fan-out — the
:class:`EngineFacade` seam (construct, step, drain, stats) keeps the
runtime engine-agnostic, and :class:`ShardedFacade` composes the whole
multi-tenant machinery with :mod:`repro.engine.sharded`'s per-device ring
shards (DESIGN.md §10):

  * **stream-tagged state** — every ring slot and every drained pair
    carries a stream id; the join masks cross-stream pairs *on device*
    (all level-1 impls), optionally with per-stream (θ, λ) looked up from
    the :class:`~repro.runtime.tenants.TenantTable`;
  * **request coalescing** — the :class:`~repro.runtime.router
    .RequestRouter` packs sub-batch arrivals from many tenants into full
    micro-batches in strict admission order, so per-arrival device cost
    tracks *output* (SWOOP's invariant per tenant), not the number of
    tenants; padding waste and queue delay are telemetered;
  * **fixed-span dispatch** — the jitted step always scans exactly
    ``span`` micro-batches (short tails ride as inert empty micro-batches
    whose strips are all dead), so the runtime compiles **once** per
    payload shape no matter how ragged the traffic;
  * **fused embed→join** — with a :class:`FusedEmbedder`, submissions are
    token batches and the LM forward + pooling + normalize runs *inside*
    the same jit program as the join scan: embeddings never round-trip
    through the host.

Determinism: uids are assigned at admission (global arrival order), the
router preserves that order exactly, and the engine scan is invariant to
micro-batch splits — so the emitted pair set is invariant to coalescing
boundaries, flush timing, and span size (tested property-style in
``tests/test_runtime.py``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..engine.engine import (
    EngineConfig,
    StreamEngineBase,
    init_telemetry,
    make_micro_step,
)
from ..distributed.sharding import DEFAULT_RULES
from ..engine.sharded import (
    init_sharded_window,
    make_sharded_batch_step,
    shard_metrics,
    shard_view,
    window_axis,
)
from ..engine.window import init_window, push_with_overflow
from ..obs import SpanTracer, merge_disjoint, publish_flat
from .router import RequestRouter, TenantBackpressure
from .tenants import TenantTable

__all__ = [
    "EngineFacade",
    "FusedEmbedder",
    "MultiTenantRuntime",
    "ShardedFacade",
    "SingleDeviceFacade",
    "make_tenant_batch_step",
    "TenantBackpressure",
]

_EMPTY_T = 3.0e30   # timestamp of inert pad rows in empty micro-batches


@dataclasses.dataclass(frozen=True)
class FusedEmbedder:
    """Embed-inside-the-join configuration for token submissions.

    ``model_cfg.d_model`` must equal ``EngineConfig.d``; ``seq_len`` fixes
    the token payload width (one compiled shape).  The embedding math is
    :func:`repro.serving.embedder.pooled_unit_embed` — the same function
    the host-side :class:`~repro.serving.embedder.LMEmbedder` jits, which
    is what makes fused and host-round-trip results bit-identical.
    """

    model_cfg: ModelConfig
    params: Any
    seq_len: int


class EngineFacade:
    """Construct/step/drain/stats seam between the runtime and an engine.

    The runtime itself is engine-agnostic: it owns admission, coalescing,
    uid→tenant attribution, and the host drain (inherited from
    :class:`~repro.engine.engine.StreamEngineBase`, whose layout contract —
    one merged :class:`~repro.kernels.sssj_join.PairBuffer` segment per
    micro-batch plus an OR-reduced row mask — both engines satisfy).  A
    facade supplies the four engine-specific pieces:

      * **construct** — :meth:`init_state` / :meth:`init_telemetry` build
        the window state (with the ``sids`` lane and the per-tenant policy
        lanes, DESIGN.md §11) and the telemetry carry;
      * **step** — :meth:`make_step` builds the jitted stream-tagged batch
        step ``(state, telem, qs, tqs, uqs, sqs, nvs) → (state, telem,
        bufs, masks)``;
      * **drain** — :meth:`global_capacity` sizes the dense-equivalent
        traffic accounting the drain reports;
      * **stats** — :meth:`metrics_extra` surfaces engine-specific
        counters (e.g. per-shard liveness) as a flat namespaced dict the
        runtime publishes into the shared registry (DESIGN.md §12).
    """

    def init_state(self, cfg: EngineConfig, table: TenantTable):
        raise NotImplementedError

    def init_telemetry(self, cfg: EngineConfig):
        raise NotImplementedError

    def make_step(
        self,
        cfg: EngineConfig,
        table: TenantTable,
        fused: Optional[FusedEmbedder],
    ):
        raise NotImplementedError

    def global_capacity(self, cfg: EngineConfig) -> int:
        raise NotImplementedError

    def metrics_extra(self, state, telem) -> dict:
        return {}


class SingleDeviceFacade(EngineFacade):
    """Default facade: one ring window on one device."""

    def init_state(self, cfg: EngineConfig, table: TenantTable):
        # per-tenant policy lanes are always materialized in the runtime:
        # overflow attribution is per-victim-stream under every policy
        return init_window(
            cfg.capacity, cfg.d, n_lanes=table.n_tenants,
            eviction=cfg.eviction,
            summary_block_w=cfg.block_w if cfg.gate_enabled else None,
            summary_chunk_d=cfg.chunk_d,
        )

    def init_telemetry(self, cfg: EngineConfig):
        return init_telemetry()

    def make_step(self, cfg, table, fused):
        return make_tenant_batch_step(cfg, table, fused)

    def global_capacity(self, cfg: EngineConfig) -> int:
        return cfg.capacity


class ShardedFacade(EngineFacade):
    """Sharded facade: one ring shard per device along the window axis.

    ``cfg.capacity`` stays the *per-shard* ring size (global window =
    ``capacity × n_shards``, same contract as
    :class:`~repro.engine.sharded.ShardedStreamEngine`); ``cfg.max_pairs``
    stays the global per-micro-batch budget.  ``cfg.micro_batch`` must be
    divisible by the shard count (round-robin deal).  The fused
    embed→join path is single-device only for now.
    """

    def __init__(self, mesh, rules=DEFAULT_RULES, axis: Optional[str] = None) -> None:
        self.mesh = mesh
        self.axis = axis or window_axis(mesh, rules)
        self.n_shards = int(mesh.shape[self.axis])

    def init_state(self, cfg: EngineConfig, table: TenantTable):
        return init_sharded_window(
            cfg, self.mesh, self.axis, n_lanes=table.n_tenants
        )

    def init_telemetry(self, cfg: EngineConfig):
        # lanes 0..n-1 per shard + lane n for the global-merge correction
        n = self.n_shards + 1
        return jax.tree.map(lambda x: jnp.zeros((n,), x.dtype), init_telemetry())

    def make_step(self, cfg, table, fused):
        if fused is not None:
            raise NotImplementedError(
                "fused embed→join is single-device only; submit vectors "
                "(or embed on the host) when running on ShardedFacade"
            )
        return make_sharded_batch_step(cfg, self.mesh, self.axis, table=table)

    def global_capacity(self, cfg: EngineConfig) -> int:
        return cfg.capacity * self.n_shards

    def metrics_extra(self, state, telem) -> dict:
        return shard_metrics(state, telem, self.n_shards)


def make_tenant_batch_step(
    cfg: EngineConfig,
    table: TenantTable,
    fused: Optional[FusedEmbedder] = None,
):
    """Jitted multi-tenant request step (single device).

    Signature: ``(state, telem, qs, tqs, uqs, sqs, nvs) → (state, telem,
    bufs, masks)`` — :func:`repro.engine.engine.make_batch_step` plus the
    ``sqs (n_micro, mb)`` stream-id lane; with ``fused``, ``qs`` is a
    token stack ``(n_micro, mb, seq_len)`` i32 and the step's signature
    gains a leading non-donated ``params`` pytree.  State and telemetry
    are donated.
    """
    tau = table.tau_max
    quo = cfg.quotas_device()

    def ingest(state, q, tq, uq, n_valid, t_max, sq):
        return push_with_overflow(
            state, q, tq, uq, n_valid, t_max, tau, sq=sq,
            eviction=cfg.eviction, quotas=quo,
            summary_block_w=cfg.block_w, summary_chunk_d=cfg.chunk_d,
        )

    if fused is None:
        def batch_step(state, telem, qs, tqs, uqs, sqs, nvs):
            micro = make_micro_step(cfg, ingest, tenant_lookup=table.lookup)
            (state, telem), (bufs, masks) = jax.lax.scan(
                micro, (state, telem), (qs, tqs, uqs, sqs, nvs)
            )
            return state, telem, bufs, masks

        return jax.jit(batch_step, donate_argnums=(0, 1))

    # imported lazily: serving.service imports this package for the
    # multi-tenant service facade, so a module-level import would cycle
    from ..serving.embedder import pooled_unit_embed

    model_cfg = fused.model_cfg

    def fused_step(params, state, telem, qs, tqs, uqs, sqs, nvs):
        def embed_fn(toks):
            return pooled_unit_embed(params, model_cfg, toks)

        micro = make_micro_step(
            cfg, ingest, tenant_lookup=table.lookup, embed_fn=embed_fn
        )
        (state, telem), (bufs, masks) = jax.lax.scan(
            micro, (state, telem), (qs, tqs, uqs, sqs, nvs)
        )
        return state, telem, bufs, masks

    return jax.jit(fused_step, donate_argnums=(1, 2))


class MultiTenantRuntime(StreamEngineBase):
    """K logical streams multiplexed onto one stream-tagged engine.

    The engine is pluggable via ``engine=`` (an :class:`EngineFacade`;
    default :class:`SingleDeviceFacade`, pass :class:`ShardedFacade(mesh)
    <ShardedFacade>` to spread the ring window over a device mesh —
    emissions are identical either way, DESIGN.md §10).

    ``submit(tenant, data, ts)`` admits a (possibly tiny) batch and
    returns its global uids; ``flush()`` coalesces everything queued into
    full micro-batches and dispatches them in fixed ``span``-sized scans
    (``flush(final=True)`` also pads out a trailing partial micro-batch);
    ``drain_by_tenant()`` returns each tenant's emitted pairs.  The
    inherited :meth:`drain_arrays` / :meth:`stats` keep working on the
    global stream.

    Timestamps should be globally non-decreasing in admission order —
    correctness never depends on it, but window eviction and the scan
    impl's live-strip walk are tuned for it (same contract as the
    single-tenant engine).
    """

    def __init__(
        self,
        cfg: EngineConfig,
        table: TenantTable,
        *,
        span: int = 4,
        max_queue_per_tenant: int = 65536,
        fused: Optional[FusedEmbedder] = None,
        engine: Optional[EngineFacade] = None,
    ) -> None:
        if cfg.emit_dense:
            raise ValueError("emit_dense is the single-tenant test oracle")
        if table.is_uniform:
            # uniform tenants keep the static-scalar join path; the table's
            # values are authoritative, so fold them into the config
            th, lm = table.spec(0)
            cfg = dataclasses.replace(cfg, theta=th, lam=lm)
        if fused is not None and fused.model_cfg.d_model != cfg.d:
            raise ValueError(
                f"fused embedder d_model ({fused.model_cfg.d_model}) must "
                f"equal EngineConfig.d ({cfg.d})"
            )
        if cfg.quotas is not None and len(cfg.quotas) != table.n_tenants:
            raise ValueError(
                f"quota table has {len(cfg.quotas)} entries but the tenant "
                f"table has {table.n_tenants} streams"
            )
        if span < 1:
            raise ValueError("span must be ≥ 1")
        super().__init__(cfg)
        self.table = table
        self.span = span
        self.fused = fused
        self.engine = engine or SingleDeviceFacade()
        self.router = RequestRouter(
            table.n_tenants, max_queue_per_tenant=max_queue_per_tenant
        )
        self.state = self.engine.init_state(cfg, table)
        self.telem = self.engine.init_telemetry(cfg)
        self._step = self.engine.make_step(cfg, table, fused)
        # observability (DESIGN.md §12): the engine registry (created by
        # StreamEngineBase.__init__) is the single stats surface — the
        # runtime adds router/tenant collectors, pipeline spans, and
        # admission→emission latency histograms to the same instance
        self.tracer = SpanTracer(self.registry)
        self._lat_hist = self.registry.histogram("latency/admit_to_emit_s")
        self._lat_by_tenant = [
            self.registry.histogram(f"tenant/{t}/latency_s")
            for t in range(table.n_tenants)
        ]
        # (sids, t_admit) per dispatch, FIFO — drained records arrive in
        # dispatch order (single copy worker), so attribution zips exactly
        self._dispatch_meta: Deque[Tuple[np.ndarray, np.ndarray]] = deque()
        self.registry.register_collector(self._publish_runtime_metrics)
        # uid → tenant map: a doubling-growth append buffer (4 B per item
        # ever admitted — see ROADMAP on tenant-aware state)
        self._uid_tenant_buf = np.empty((1024,), np.int32)
        self._uid_tenant_n = 0
        self._mask_uid0 = 0          # first uid the next drain's mask covers
        self.padded_rows = 0         # inert rows in real micro-batches
        self.empty_micro_batches = 0  # span-fill micro-batches (all dead)
        self.spans_dispatched = 0
        self.submitted_by_tenant: Dict[int, int] = {
            t: 0 for t in range(table.n_tenants)
        }
        self.pairs_by_tenant: Dict[int, int] = {
            t: 0 for t in range(table.n_tenants)
        }

    # ------------------------------------------------------------------ #
    def push(self, vecs, ts):  # pragma: no cover - guardrail
        raise NotImplementedError(
            "MultiTenantRuntime routes arrivals through submit()/flush()"
        )

    def submit(
        self, tenant: int, data: np.ndarray, ts: np.ndarray
    ) -> np.ndarray:
        """Admit one tenant's batch; returns its global uids.

        ``data`` is ``(b, d)`` float vectors (callers normalize), or
        ``(b, seq_len)`` int tokens in fused mode.  Nothing reaches the
        device until :meth:`flush`.  Raises
        :class:`~repro.runtime.router.TenantBackpressure` (admitting
        nothing) when the tenant's queue cap would be exceeded.
        """
        tenant = self.table.validate_id(tenant)
        ts = np.asarray(ts, np.float64).reshape(-1)
        if self.fused is not None:
            data = np.asarray(data, np.int32)
            if data.ndim != 2 or data.shape[1] != self.fused.seq_len:
                raise ValueError(
                    f"fused submissions must be (b, {self.fused.seq_len}) "
                    f"tokens, got {data.shape}"
                )
        else:
            data = np.asarray(data, np.float32)
            if data.ndim != 2 or data.shape[1] != self.cfg.d:
                raise ValueError(
                    f"submissions must be (b, {self.cfg.d}) vectors, "
                    f"got {data.shape}"
                )
        b = data.shape[0]
        if b != ts.shape[0]:
            raise ValueError(f"{b} rows but {ts.shape[0]} timestamps")
        if b == 0:
            return np.empty((0,), np.int32)
        uids = np.arange(self._next_uid, self._next_uid + b, dtype=np.int32)
        with self.tracer.span("admit"):
            self.router.admit(tenant, data, ts, uids)  # all-or-nothing
        self._next_uid += b
        n = self._uid_tenant_n
        if n + b > self._uid_tenant_buf.size:
            grown = np.empty((max(2 * self._uid_tenant_buf.size, n + b),),
                             np.int32)
            grown[:n] = self._uid_tenant_buf[:n]
            self._uid_tenant_buf = grown
        self._uid_tenant_buf[n:n + b] = tenant
        self._uid_tenant_n = n + b
        self.submitted_by_tenant[tenant] += b
        return uids

    # ------------------------------------------------------------------ #
    def _dispatch(self, payload, ts, uids, sids, t_admit) -> None:
        """Pack one span of micro-batches and launch the device step."""
        cfg = self.cfg
        mb, span = cfg.micro_batch, self.span
        rows = span * mb
        n = payload.shape[0]
        assert n <= rows
        n_real = -(-n // mb)                     # micro-batches with any data
        pad = rows - n
        with self.tracer.span("coalesce"):
            if self.fused is not None:
                pl = np.zeros((rows, self.fused.seq_len), np.int32)
            else:
                pl = np.zeros((rows, cfg.d), np.float32)
            pl[:n] = payload
            tq = np.full(rows, _EMPTY_T, np.float32)  # inert: all strips dead
            tq[:n] = ts
            if n and n_real * mb > n:
                # partial tail micro-batch: repeat its last valid timestamp
                # so the strip filter's extremes stay honest (pad_request
                # contract)
                tq[n:n_real * mb] = ts[-1]
            uq = np.full(rows, -1, np.int32)
            uq[:n] = uids
            sq = np.full(rows, -1, np.int32)
            sq[:n] = sids
            nvs = np.clip(n - mb * np.arange(span), 0, mb).astype(np.int32)

        with self.tracer.span("h2d"):
            args = (
                jnp.asarray(pl.reshape(span, mb, -1)),
                jnp.asarray(tq.reshape(span, mb)),
                jnp.asarray(uq.reshape(span, mb)),
                jnp.asarray(sq.reshape(span, mb)),
            )
        with self.tracer.span("scan"):
            # dispatch time only — jax executes asynchronously; device wall
            # time hides in the drain span (see repro.obs.spans)
            if self.fused is not None:
                self.state, self.telem, bufs, masks = self._step(
                    self.fused.params, self.state, self.telem, *args, nvs
                )
            else:
                self.state, self.telem, bufs, masks = self._step(
                    self.state, self.telem, *args, nvs
                )
        self._dispatch_meta.append((sids, t_admit))
        self._pending.append(self._copier.submit(self._fetch, bufs, masks, nvs))
        self.n_items += n
        # padding waste = inert rows inside *real* micro-batches (they ride
        # through the join); span-fill micro-batches are separate — their
        # strips are all dead, so they cost scan steps but no join work
        self.padded_rows += n_real * mb - n
        self.empty_micro_batches += self.span - n_real
        self.spans_dispatched += 1
        # dense-equivalent traffic counts real micro-batches only (what the
        # dense path would actually have fetched for this data)
        self.bytes_dense_equiv += n_real * 4 * (
            mb * self._global_capacity() + mb * mb
        )

    def flush(self, final: bool = False) -> int:
        """Coalesce queued arrivals into micro-batches and dispatch them.

        Dispatches every *full* micro-batch (in span-sized scans; a short
        span rides out with inert empty micro-batches).  Rows short of a
        micro-batch stay queued for the next flush — unless ``final=True``,
        which pads the tail out (the end-of-stream / latency-deadline
        case).  Returns the number of real rows dispatched.
        """
        mb = self.cfg.micro_batch
        rows_span = mb * self.span
        sent = 0
        while len(self.router) >= rows_span:
            self._dispatch(*self.router.take(rows_span))
            sent += rows_span
        rem = len(self.router)
        take_n = rem if final else (rem // mb) * mb
        if take_n:
            self._dispatch(*self.router.take(take_n))
            sent += take_n
        return sent

    # ------------------------------------------------------------------ #
    def _tenant_of(self, uids: np.ndarray) -> np.ndarray:
        return self._uid_tenant_buf[:self._uid_tenant_n][uids]

    def drain_arrays(self, return_masks: bool = False):
        """As :meth:`StreamEngineBase.drain_arrays`, tracking the uid range
        each drain's masks cover so per-tenant attribution stays aligned
        however the caller mixes global and per-tenant drains."""
        ua, ub, sc, mask = super().drain_arrays(return_masks=True)
        self._mask_uid0 += mask.shape[0]
        if return_masks:
            return ua, ub, sc, mask
        return ua, ub, sc

    def drain_by_tenant(
        self, return_masks: bool = False
    ) -> Dict[int, Tuple[np.ndarray, ...]]:
        """Everything emitted since the last drain, grouped by stream.

        Returns ``{tenant: (uid_a, uid_b, score)}`` (uids are global; map
        back with the uids :meth:`submit` returned).  With
        ``return_masks=True`` each tuple gains the tenant's per-row match
        masks, aligned with its dispatched uids in admission order.  Pair
        attribution uses ``uid_a``'s stream — the join's stream-equality
        mask guarantees ``uid_b`` agrees.
        """
        with self.tracer.span("emit"):
            return self._drain_by_tenant(return_masks)

    def _drain_by_tenant(
        self, return_masks: bool = False
    ) -> Dict[int, Tuple[np.ndarray, ...]]:
        ua, ub, sc, mask = self.drain_arrays(return_masks=True)
        mask_uids = np.arange(
            self._mask_uid0 - mask.shape[0], self._mask_uid0, dtype=np.int64
        )
        k = self.table.n_tenants
        tids = np.arange(k)

        def group(keys, *values):
            # one stable sort + K boundary lookups — O(n log n + K), not a
            # full-array scan per tenant; stable keeps emission/admission
            # order within each tenant
            order = np.argsort(keys, kind="stable")
            ks = keys[order]
            lo = np.searchsorted(ks, tids)
            hi = np.searchsorted(ks, tids, side="right")
            return [
                tuple(v[order[a:b]] for v in values)
                for a, b in zip(lo, hi)
            ]

        pair_t = self._tenant_of(ua) if ua.size else np.empty((0,), np.int32)
        mask_t = (
            self._tenant_of(mask_uids) if mask.size else np.empty((0,), np.int32)
        )
        pair_groups = group(pair_t, ua, ub, sc)
        mask_groups = group(mask_t, mask) if return_masks else None
        out: Dict[int, Tuple[np.ndarray, ...]] = {}
        for t in range(k):
            rec: Tuple[np.ndarray, ...] = pair_groups[t]
            self.pairs_by_tenant[t] += rec[0].size
            if return_masks:
                rec = rec + mask_groups[t]
            out[t] = rec
        return out

    # ------------------------------------------------------------------ #
    def tenant_stats(self, tenant: int) -> dict:
        tenant = self.table.validate_id(tenant)
        th, lm = self.table.spec(tenant)
        by_tenant = self.overflow_by_tenant
        return {
            "theta": th,
            "lam": lm,
            "submitted": self.submitted_by_tenant[tenant],
            "queued": self.router.queued_by_tenant[tenant],
            "pairs_drained": self.pairs_by_tenant[tenant],
            # this tenant's live items lost to overwrite (victim-side
            # attribution, DESIGN.md §11) — no longer the global-only count
            "window_overflow": int(by_tenant[tenant]),
            "quota": (
                None if self.cfg.quotas is None
                else int(self.cfg.quotas[tenant])
                * self.engine.global_capacity(self.cfg) // self.cfg.capacity
            ),
        }

    def _global_capacity(self) -> int:
        return self.engine.global_capacity(self.cfg)

    # ------------------------------------------------------------------ #
    def _observe_emission(self, t_done: float, fetch_s: float) -> None:
        """Attribute one drained record's admission→emission latency.

        Records leave :meth:`_drain` in dispatch order (single copy
        worker, FIFO futures) and ``push()`` is disabled, so each record
        pairs with exactly one ``(sids, t_admit)`` entry queued by
        :meth:`_dispatch`.
        """
        self.tracer.record("drain", fetch_s)
        if not self._dispatch_meta:     # pragma: no cover - defensive
            return
        sids, t_admit = self._dispatch_meta.popleft()
        lat = np.maximum(t_done - t_admit, 0.0)
        self._lat_hist.observe_many(lat)
        for t in np.unique(sids):
            self._lat_by_tenant[int(t)].observe_many(lat[sids == t])

    def _publish_runtime_metrics(self, reg) -> None:
        """Snapshot-time collector: router/runtime/per-tenant counters
        under the namespaced schema (DESIGN.md §12), alongside the engine
        collector registered by :class:`StreamEngineBase`."""
        rt = self.router.telemetry
        c, g = reg.counter, reg.gauge
        c("router/items_admitted").set(rt.items_admitted)
        c("router/items_rejected").set(rt.items_rejected)
        c("router/items_dispatched").set(rt.items_dispatched)
        c("router/queue_delay_sum_s").set(rt.queue_delay_sum_s)
        g("router/queue_delay_max_s").set(rt.queue_delay_max_s)
        g("router/items_queued").set(len(self.router))
        reg.info("runtime/eviction").set(self.cfg.eviction)
        g("runtime/n_tenants").set(self.table.n_tenants)
        c("runtime/spans_dispatched").set(self.spans_dispatched)
        c("runtime/padded_rows").set(self.padded_rows)
        c("runtime/empty_micro_batches").set(self.empty_micro_batches)
        for t in range(self.table.n_tenants):
            c(f"tenant/{t}/submitted").set(self.submitted_by_tenant[t])
            g(f"tenant/{t}/queued").set(self.router.queued_by_tenant[t])
            c(f"tenant/{t}/pairs_drained").set(self.pairs_by_tenant[t])
        publish_flat(reg, self.engine.metrics_extra(self.state, self.telem))

    def stats(self) -> dict:
        """Legacy flat stats — a compatibility view derived from one
        registry snapshot, so every value equals its namespaced metric."""
        snap = self.registry.snapshot()
        disp = snap["router/items_dispatched"]
        padded = snap["runtime/padded_rows"]
        runtime_view = {
            "eviction": snap["runtime/eviction"],
            "n_tenants": snap["runtime/n_tenants"],
            "items_queued": snap["router/items_queued"],
            "items_rejected": snap["router/items_rejected"],
            "spans_dispatched": snap["runtime/spans_dispatched"],
            "padded_rows": padded,
            "empty_micro_batches": snap["runtime/empty_micro_batches"],
            "padding_waste": padded / max(padded + disp, 1),
            "queue_delay_mean_s": snap["router/queue_delay_sum_s"]
            / max(disp, 1),
            "queue_delay_max_s": snap["router/queue_delay_max_s"],
        }
        shard = shard_view(snap) if "engine/n_shards" in snap else {}
        return merge_disjoint(
            self._legacy_engine_view(snap), shard, runtime_view
        )
