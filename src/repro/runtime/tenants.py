"""Per-stream join parameters: the tenant table.

The paper runs one stream under one global ``(θ, λ)``.  A service
multiplexing thousands of logical streams wants per-tenant retention
semantics ("Fishing in the Stream": each consumer has its own horizon and
quality bar), so the runtime keeps a small device-resident table of
``(θ_k, λ_k)`` and the join looks a row's parameters up by its stream id
(DESIGN.md §9).  A pair's stream is its query row's stream — the join's
stream-equality mask guarantees both sides agree — so query-side values
govern the whole pair.

The table is deliberately tiny (K scalars per field): it is closed over by
the jitted batch step and becomes a compile-time constant, so changing a
tenant's parameters means building a new runtime step — the same contract
as changing ``EngineConfig``.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.similarity import time_horizon

__all__ = ["TenantTable"]


class TenantTable:
    """Immutable per-stream ``(theta, lam)`` table with device mirrors.

    ``thetas``/``lams`` are host float arrays of length ``n_tenants``;
    ``lookup`` is what the jitted micro step calls to turn a stream-id lane
    into per-row parameter lanes (or ``None`` when every tenant shares the
    same values, which keeps the faster static-scalar join path).
    """

    def __init__(self, thetas: Sequence[float], lams: Sequence[float]) -> None:
        thetas = np.asarray(thetas, np.float32).reshape(-1)
        lams = np.asarray(lams, np.float32).reshape(-1)
        if thetas.size == 0:
            raise ValueError("tenant table must have at least one stream")
        if thetas.shape != lams.shape:
            raise ValueError(
                f"thetas ({thetas.shape}) and lams ({lams.shape}) disagree"
            )
        for k, (th, lm) in enumerate(zip(thetas.tolist(), lams.tolist())):
            if not 0.0 < th <= 1.0:
                raise ValueError(f"tenant {k}: theta must be in (0, 1], got {th}")
            if lm < 0.0:
                raise ValueError(f"tenant {k}: lam must be ≥ 0, got {lm}")
        self.thetas = thetas
        self.lams = lams
        self._theta_d = jnp.asarray(thetas)
        self._lam_d = jnp.asarray(lams)

    @classmethod
    def uniform(cls, n_tenants: int, theta: float, lam: float) -> "TenantTable":
        return cls([theta] * n_tenants, [lam] * n_tenants)

    # ------------------------------------------------------------------ #
    @property
    def n_tenants(self) -> int:
        return int(self.thetas.size)

    @property
    def is_uniform(self) -> bool:
        return bool(
            np.all(self.thetas == self.thetas[0])
            and np.all(self.lams == self.lams[0])
        )

    @property
    def tau_max(self) -> float:
        """The widest tenant horizon — what sizes the shared ring window
        (and its live-slot overflow accounting, conservatively)."""
        return max(
            time_horizon(float(t), float(l))
            for t, l in zip(self.thetas, self.lams)
        )

    @property
    def device_tables(self) -> Tuple[jax.Array, jax.Array]:
        """Device-resident ``(thetas, lams)`` arrays — what the sharded
        engine broadcasts (replicated) through its shard_map in_specs so
        each shard can run :meth:`lookup_rows` locally."""
        return self._theta_d, self._lam_d

    def spec(self, tenant: int) -> Tuple[float, float]:
        return float(self.thetas[tenant]), float(self.lams[tenant])

    def validate_id(self, tenant: int) -> int:
        tenant = int(tenant)
        if not 0 <= tenant < self.n_tenants:
            raise ValueError(
                f"unknown stream id {tenant} (table has {self.n_tenants})"
            )
        return tenant

    # ------------------------------------------------------------------ #
    @staticmethod
    def lookup_rows(
        theta_d: jax.Array, lam_d: jax.Array, sq: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        """Row lookup from explicit device tables (traced).

        The shard_map form of :meth:`lookup`: the sharded engine passes the
        tables as replicated in_specs arguments instead of closure
        constants, so the lookup stays explicit in the sharded jaxpr.  Pad
        rows carry ``sq = -1``; the clip sends them to tenant 0, whose
        finite values are inert — pad rows can never emit (uid = -1) and
        never loosen the min-based pruning bounds.
        """
        idx = jnp.clip(sq.astype(jnp.int32), 0, theta_d.shape[0] - 1)
        return theta_d[idx], lam_d[idx]

    def lookup(
        self, sq: jax.Array
    ) -> Optional[Tuple[jax.Array, jax.Array]]:
        """Stream-id lane → per-row ``(theta_q, lam_q)`` lanes (traced).

        Returns ``None`` for uniform tables so the join keeps its static
        scalars (identical results, one fewer lane through the kernel).
        """
        if self.is_uniform:
            return None
        return self.lookup_rows(self._theta_d, self._lam_d, sq)
