"""repro.runtime — multi-tenant streaming runtime (DESIGN.md §9).

Multiplexes K independent logical streams onto one device-resident
engine:

  * :mod:`~repro.runtime.tenants` — per-stream ``(θ, λ)`` device table;
  * :mod:`~repro.runtime.router` — admission queue / request coalescer
    with per-tenant backpressure and padding/queue-delay telemetry;
  * :mod:`~repro.runtime.runtime` — :class:`MultiTenantRuntime`: the
    stream-tagged engine facade (fixed-span dispatch, per-tenant drain)
    and the optional fused embed→join path (:class:`FusedEmbedder`).
"""

from .router import (  # noqa: F401
    RequestRouter,
    RouterTelemetry,
    TenantBackpressure,
)
from .runtime import (  # noqa: F401
    EngineFacade,
    FusedEmbedder,
    MultiTenantRuntime,
    ShardedFacade,
    SingleDeviceFacade,
    make_tenant_batch_step,
)
from .tenants import TenantTable  # noqa: F401
