"""Training substrate: loss, train-step builder, gradient compression."""

from .loss import cross_entropy_loss  # noqa: F401
from .step import TrainConfig, build_train_step, init_train_state  # noqa: F401
