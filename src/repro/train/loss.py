"""Cross-entropy loss with z-loss, safe under a vocab-sharded logits axis.

The logits' vocab axis is sharded over ``model`` (see lm_specs); the
log-sum-exp below reduces over it, which GSPMD lowers to an all-reduce —
no full-vocab gather is ever materialized.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["cross_entropy_loss"]


def cross_entropy_loss(
    logits: jax.Array,          # (B, S, V)
    labels: jax.Array,          # (B, S) int32
    mask: Optional[jax.Array] = None,   # (B, S) 1.0 = count
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, dict]:
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]   # (B,S)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / denom
    acc = ((jnp.argmax(lf, axis=-1) == labels).astype(jnp.float32) * mask).sum() / denom
    return loss, {
        "nll": (nll * mask).sum() / denom,
        "z_loss": (zl * mask).sum() / denom,
        "accuracy": acc,
        "tokens": mask.sum(),
    }
