"""Train-step builder: remat, microbatch gradient accumulation, AdamW.

``build_train_step(model_cfg, train_cfg)`` returns a pure function

    (params, opt_state, batch) → (params, opt_state, metrics)

suitable for ``jax.jit`` with sharded inputs.  Features:

  * mixed precision: fp32 params, bf16 compute (cast at the boundary);
  * activation remat of every scanned block (``remat=True``);
  * microbatch gradient accumulation via ``lax.scan`` (grads accumulated in
    fp32), letting the global batch exceed per-device activation memory;
  * MoE load-balance aux loss and the DeepSeek-V3 MTP head when configured;
  * AdamW with fp32/bf16/int8 moments and warmup-cosine schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.lm import init_lm, lm_forward, lm_specs, mtp_logits
from ..optim.adamw import AdamWConfig, apply_adamw, init_opt_state, opt_state_specs
from ..optim.schedule import warmup_cosine
from .loss import cross_entropy_loss

__all__ = ["TrainConfig", "build_train_step", "init_train_state", "train_state_specs"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: bool = True
    microbatches: int = 1
    aux_weight: float = 0.01      # MoE load-balance loss weight
    mtp_weight: float = 0.3       # DeepSeek-V3 MTP loss weight
    z_loss: float = 1e-4
    compute_dtype: str = "bfloat16"


def _dtype(t: TrainConfig):
    return jnp.bfloat16 if t.compute_dtype == "bfloat16" else jnp.float32


def init_train_state(key, model_cfg: ModelConfig, train_cfg: TrainConfig):
    params = init_lm(key, model_cfg)
    opt_state = init_opt_state(params, train_cfg.optimizer)
    return params, opt_state


def train_state_specs(model_cfg: ModelConfig, train_cfg: TrainConfig):
    p = lm_specs(model_cfg)
    return p, opt_state_specs(p, train_cfg.optimizer)


def _loss_fn(params, batch, model_cfg: ModelConfig, t: TrainConfig):
    dt = _dtype(t)
    kw: Dict[str, Any] = dict(compute_dtype=dt, remat=t.remat)
    if model_cfg.input_kind == "embeddings":
        fwd_in = dict(embeds=batch["embeds"])
    else:
        fwd_in = dict(tokens=batch["tokens"])
    need_hidden = bool(model_cfg.mtp)
    out = lm_forward(
        params, model_cfg, **fwd_in, **kw, return_hidden=need_hidden
    )
    if need_hidden:
        logits, aux, _, hidden = out
    else:
        logits, aux, _ = out
    mask = batch.get("mask")
    loss, metrics = cross_entropy_loss(
        logits, batch["labels"], mask=mask, z_loss=t.z_loss
    )
    total = loss + t.aux_weight * aux
    metrics["aux_loss"] = aux
    if need_hidden and not model_cfg.input_kind == "embeddings":
        # MTP: predict t+2 with [h_t ; Emb(t_{t+1})]; target = labels shifted
        nxt = batch["labels"]                         # == tokens at t+1
        logits2 = mtp_logits(params, model_cfg, hidden, nxt, compute_dtype=dt)
        tgt2 = jnp.concatenate(
            [batch["labels"][:, 1:], batch["labels"][:, -1:]], axis=1
        )
        m2 = jnp.ones_like(tgt2, jnp.float32)
        m2 = m2.at[:, -1].set(0.0)
        if mask is not None:
            m2 = m2 * mask
        l2, _ = cross_entropy_loss(logits2, tgt2, mask=m2, z_loss=0.0)
        total = total + t.mtp_weight * l2
        metrics["mtp_loss"] = l2
    metrics["loss"] = total
    return total, metrics


def build_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    """Build ``(params, opt_state, batch) → (params, opt_state, metrics)``."""
    t = train_cfg
    oc = t.optimizer

    grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)

    def accumulate(params, batch):
        """Gradient over the whole batch, optionally in microbatches."""
        if t.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch, model_cfg, t)
            return grads, metrics

        def split(x):
            b = x.shape[0]
            assert b % t.microbatches == 0, (b, t.microbatches)
            return x.reshape((t.microbatches, b // t.microbatches) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            acc, msum = carry
            (loss, metrics), grads = grad_fn(params, mbatch, model_cfg, t)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads
            )
            msum = jax.tree.map(lambda a, b_: a + b_, msum, metrics)
            return (acc, msum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        zmet = {
            "nll": 0.0, "z_loss": 0.0, "accuracy": 0.0, "tokens": 0.0,
            "aux_loss": 0.0, "loss": 0.0,
        }
        if model_cfg.mtp and model_cfg.input_kind != "embeddings":
            zmet["mtp_loss"] = 0.0
        zmet = jax.tree.map(jnp.float32, zmet)
        (grads, msum), _ = jax.lax.scan(body, (zeros, zmet), mb)
        inv = 1.0 / t.microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m * inv, msum)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = accumulate(params, batch)
        lr = warmup_cosine(
            opt_state["step"], oc.peak_lr, oc.warmup_steps, oc.total_steps,
            oc.min_lr_ratio,
        )
        params, opt_state, opt_metrics = apply_adamw(
            params, grads, opt_state, oc, lr
        )
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
