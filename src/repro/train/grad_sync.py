"""Gradient compression for the DCN-crossing mesh axis (error feedback).

Cross-pod gradient all-reduce is the multi-pod bottleneck: the ``pod`` axis
rides DCN (~6.4 GB/s/host) while everything else rides ICI (~50 GB/s/link).
``compressed_psum`` implements int8 error-feedback compression for exactly
that axis:

  1. ``x + e`` (add the residual carried from the previous step);
  2. blockwise int8 quantize → ``q`` (payload shrinks 4× vs f32);
  3. ``jax.lax.psum(dequant(q))`` across the axis — the wire format is the
     dequantized bf16/int-scaled tensor; a production build would psum the
     int8 payload with a custom reduction, the semantics (and the error
     feedback) are identical;
  4. new residual ``e' = (x + e) − dequant(q)`` stays local.

Error feedback makes the *accumulated* compression error bounded: the
quantization noise of step t is re-injected at step t+1, so the optimizer
sees an unbiased-in-the-limit gradient (standard EF-SGD/EF21 argument).
Validated in tests against uncompressed psum trajectories.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_quantize", "ef_dequantize", "compressed_psum", "init_ef_state"]

_BLOCK = 256


def _pad_last(x, mult):
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def ef_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise (256, last axis) linear int8.  Returns (q, scale)."""
    orig_last = x.shape[-1]
    xf = _pad_last(x.astype(jnp.float32), _BLOCK)
    nb = xf.shape[-1] // _BLOCK
    blocks = xf.reshape(xf.shape[:-1] + (nb, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def ef_dequantize(q: jax.Array, scale: jax.Array, last: int) -> jax.Array:
    out = q.astype(jnp.float32) * scale[..., None]
    out = out.reshape(out.shape[:-2] + (-1,))
    return out[..., :last]


def init_ef_state(grads):
    """Zero residuals, one per gradient leaf."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, ef_state, axis_name: str):
    """Error-feedback int8 psum over ``axis_name`` (use under shard_map).

    Returns ``(mean_grads, new_ef_state)``.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = ef_quantize(x)
        deq = ef_dequantize(q, scale, x.shape[-1])
        new_e = x - deq
        summed = jax.lax.psum(deq, axis_name)
        return summed / n, new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])
