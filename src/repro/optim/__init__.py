"""Optimizer substrate (no external NN libraries).

  * :mod:`repro.optim.adamw` — AdamW with fp32 / bf16 / block-quantized-int8
    moment storage (the int8 mode is what lets the 671B config's optimizer
    state fit v5e HBM), global-norm clipping, decoupled weight decay.
  * :mod:`repro.optim.schedule` — linear-warmup + cosine decay.
"""

from .adamw import (  # noqa: F401
    AdamWConfig, QTensor, init_opt_state, opt_state_specs, apply_adamw,
)
from .schedule import warmup_cosine  # noqa: F401
