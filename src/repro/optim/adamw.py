"""AdamW with configurable moment storage: fp32 / bf16 / block-int8.

The int8 mode stores both moments as int8 with per-block (256-wide, last
axis) absmax scales — the bitsandbytes-style block quantization.  For the
671B config this cuts optimizer state from 8 bytes/param (fp32 m+v) to
~2.06 bytes/param, which is the difference between fitting and not fitting
v5e HBM at 512 chips (see EXPERIMENTS.md §Dry-run).

All state leaves inherit the parameter's logical sharding (ZeRO-style: the
``fsdp`` axis shards both params and moments), so the optimizer adds no
replicated memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig", "QTensor", "init_opt_state", "opt_state_specs", "apply_adamw",
]

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "f32"      # "f32" | "bf16" | "int8"


class QTensor(NamedTuple):
    """Block-quantized tensor: int8 payload + per-block absmax scales."""

    q: jax.Array       # int8, same shape as the source
    scale: jax.Array   # f32, shape[:-1] + (ceil(last / _BLOCK),)


def _pad_last(x: jax.Array, mult: int) -> Tuple[jax.Array, int]:
    last = x.shape[-1]
    pad = (-last) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, last


# Dynamic (power-law) 8-bit code: value = sign · (|q|/127)^4 · blockmax.
# Linear int8 cannot represent Adam's second moment (per-block dynamic range
# ≫ 127:1 → small v quantize to 0 → exploding m/√v); the quartic code spans
# (1/127)⁴ ≈ 4e-9 of the block max, the same trick as bitsandbytes' dynamic
# quantization map.  Verified against fp32 Adam trajectories in
# tests/test_optim.py.
_QPOW = 4.0


def quantize_q8(x: jax.Array) -> QTensor:
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
        scalar = True
    else:
        scalar = False
    xp, last = _pad_last(xf, _BLOCK)
    nb = xp.shape[-1] // _BLOCK
    blocks = xp.reshape(xp.shape[:-1] + (nb, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1)
    safe = jnp.where(scale > 0, scale, 1.0)
    frac = jnp.abs(blocks) / safe[..., None]
    mag = jnp.round(127.0 * frac ** (1.0 / _QPOW))
    q = (jnp.sign(blocks) * mag).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :last]
    if scalar:
        q = q[0]
        scale = scale[0]
    return QTensor(q=q, scale=scale)


def dequantize_q8(t: QTensor) -> jax.Array:
    q = t.q.astype(jnp.float32)
    scale = t.scale
    if q.ndim == 0:
        return jnp.sign(q) * (jnp.abs(q) / 127.0) ** _QPOW * scale
    qp, last = _pad_last(q, _BLOCK)
    nb = qp.shape[-1] // _BLOCK
    blocks = qp.reshape(qp.shape[:-1] + (nb, _BLOCK))
    out = jnp.sign(blocks) * (jnp.abs(blocks) / 127.0) ** _QPOW * scale[..., None]
    return out.reshape(qp.shape)[..., :last]


def _encode(x: jax.Array, mode: str):
    if mode == "int8":
        return quantize_q8(x)
    if mode == "bf16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _decode(x, mode: str) -> jax.Array:
    if mode == "int8":
        return dequantize_q8(x)
    return x.astype(jnp.float32)


def init_opt_state(params, cfg: AdamWConfig):
    # m and v must be INDEPENDENT buffers (``astype`` on a matching dtype is
    # a no-op returning the same array, and donation rejects aliased args)
    def fresh(p):
        return _encode(jnp.zeros(p.shape, jnp.float32), cfg.moment_dtype)

    return {
        "m": jax.tree.map(fresh, params),
        "v": jax.tree.map(fresh, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _moment_spec(spec, mode: str):
    """Sharding spec for one moment leaf given the param's logical spec."""
    if mode != "int8":
        return spec
    if spec is None:
        return QTensor(q=None, scale=None)
    # scale drops the last axis into blocks — shard it like the param minus
    # the last dim (replicate the block axis)
    return QTensor(q=spec, scale=tuple(spec[:-1]) + (None,) if spec else None)


def opt_state_specs(param_specs, cfg: AdamWConfig):
    is_leaf = lambda s: s is None or isinstance(s, tuple)
    mom = jax.tree.map(
        lambda s: _moment_spec(s, cfg.moment_dtype), param_specs, is_leaf=is_leaf
    )
    return {"m": mom, "v": mom, "step": None}


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def apply_adamw(
    params,
    grads,
    state,
    cfg: AdamWConfig,
    lr: jax.Array,
):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mode = cfg.moment_dtype

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = b1 * _decode(m, mode) + (1 - b1) * g
        vf = b2 * _decode(v, mode) + (1 - b2) * g * g
        mhat = mf / c1
        vhat = vf / c2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), _encode(mf, mode), _encode(vf, mode)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_q = lambda x: isinstance(x, QTensor)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {
        "grad_norm": gnorm,
        "param_norm": _global_norm(params),
        "lr": lr,
        "clip": clip,
    }
    return new_params, new_state, metrics
